"""Recursive multi-round shuffle: the library composed with itself.

The paper's single-pass sort is bounded by reduce fan-in: partition r
must stream one run per map task under reduce_memory_budget_bytes, so
dataset size is capped by budget x map tasks. serverless-sort's radix
planner (SNIPPETS.md snippet 1) shows the way out — shuffle by leading
key bits until every category fits one buffer. `recursive_sort` is that
idea expressed in this library's own terms, which is the point: every
round is a plain composed ShuffleJob.

Round structure:

  sample  — `shuffle/job.sample_boundaries` reads a deterministic,
      evenly spaced `plan.sample_fraction` of the input through ranged
      GETs (billed + traced as its own phase, "sample") and produces the
      Daytona-style quantile splitters that replace the equal Indy
      split in BOTH the device keyspace routing and the host
      RangePartitioner.

  round 1 — the normal device-path sort job (shuffle/sort.SortMapOp +
      MergeReduceOp), except partitions the sample PREDICTS will exceed
      the reduce budget are *redirected*: their reduce doesn't k-way
      merge at all — a _ConcatSink concatenates run slices (drained
      sequentially, one cursor at a time, budget grant of ONE run) into
      a staged object under `<output_prefix minus '/'>.rounds/`. The
      fan-in ceiling vanishes for exactly the partitions that would
      have hit it.

  observe — any non-redirected output the round *measures* oversized
      (the sampler missed it, or sampling was off) is restaged by a
      copy and recursed too, so the guarantee doesn't depend on sample
      quality.

  round d>1 — every staged partition becomes a child ShuffleJob over
      its own three disjoint prefixes. The child partitions by "the
      next key bits": the routed domain is the high 32 bits of
      (key<<32|id - lo64) >> shift over the parent partition's packed
      sub-range — for a parent range wider than one key these are the
      unconsumed key bits; for a single duplicated hot key the route
      degenerates to the record id, which splits a partition no key
      boundary can. Child map tasks host-sort (stable, by packed
      key<<32|id) the staged chunks; child outputs land at
      `<parent output key>/sub-NNNNN`, which list_objects orders
      exactly where the parent object would have been — so
      valsort.validate_from_store streams the final prefix unchanged.

Determinism: sample positions, predictions, redirects, observation,
concat order (source order), and child boundaries are all pure
arithmetic over the input — no RNG, no wall clock — so the final output
bytes and etags are identical at any worker count, parallelism, or
under worker kills/speculation (pinned by tests/test_shuffle.py and the
tests/chaos.py recursive-kill schedule). Staging lives under the
durable output tier, never under spill_prefix, so a dead worker's
correlated spill-tier loss cannot destroy a committed round input.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

import numpy as np

from repro.io import records as rec
from repro.io.backends import StoreBackend
from repro.obs.events import Tracer

from repro.shuffle import runtime as _rt
from repro.shuffle.api import (MapOp, PartitionReducer, Partitioner,
                               ReduceOp, require)
from repro.shuffle.job import KeySample, ShuffleJob, sample_boundaries
from repro.shuffle.partition import RangePartitioner

def recurse_prefix(plan) -> str:
    """Staging root for recursive rounds: a sibling of output_prefix
    (`output.rounds/` next to `output/`) — lexicographically disjoint
    from input/spill/output, so no session preflight or final listing
    ever sweeps staged round inputs."""
    return plan.output_prefix.rstrip("/") + ".rounds/"


@dataclasses.dataclass(frozen=True)
class KeyRoute:
    """Monotone map from one parent partition's packed (key<<32|id)
    sub-range [lo64, hi64) onto a uint32 routed domain — "the next key
    bits": (k64 - lo64) >> shift with the smallest shift that fits the
    span into 32 bits. Order-preserving in (key, id), so sub-partition
    concatenation is globally sorted; a single-key parent range
    degenerates to routing by id."""

    lo64: int
    hi64: int

    @property
    def shift(self) -> int:
        span = self.hi64 - self.lo64
        return max(0, (span - 1).bit_length() - 32) if span > 1 else 0

    @property
    def routed_span(self) -> int:
        """Number of distinct routed values (<= 2^32)."""
        return -(-(self.hi64 - self.lo64) // (1 << self.shift))

    def routed(self, keys: np.ndarray, ids: np.ndarray) -> np.ndarray:
        k64 = (np.asarray(keys, np.uint64) << np.uint64(32)) | np.asarray(
            ids, np.uint64)
        return ((k64 - np.uint64(self.lo64))
                >> np.uint64(self.shift)).astype(np.uint32)

    def equal_bounds(self, parts: int) -> np.ndarray:
        """(parts-1,) equal split of the routed span — the sampling-off
        fallback (pure radix: equal ranges of the next key bits)."""
        js = np.arange(1, parts, dtype=np.uint64)
        return ((js * np.uint64(self.routed_span))
                // np.uint64(parts)).astype(np.uint32)

    def sub_range64(self, routed_bounds: np.ndarray,
                    j: int) -> tuple[int, int]:
        """Packed sub-range [lo64, hi64) of child partition j under
        `routed_bounds` — the preimage of routed range j, clipped to the
        parent range."""
        parts = len(routed_bounds) + 1
        lo = (self.lo64 if j == 0
              else self.lo64 + (int(routed_bounds[j - 1]) << self.shift))
        hi = (self.hi64 if j == parts - 1
              else min(self.hi64,
                       self.lo64 + (int(routed_bounds[j]) << self.shift)))
        return lo, hi


class SubrangePartitioner(Partitioner):
    """Order-preserving partitioner for a recursive round: boundaries
    live in the parent sub-range's routed (next key bits) domain.

    `partition_of` routes raw uint32 keys with id=0 — ties on a
    duplicated key all land in the lowest candidate sub-partition, which
    keeps the monotone/exhaustive partitioner properties. The exact
    per-record routing (keys AND ids) is `partition_of64`, the one the
    map op's spill offsets use."""

    def __init__(self, num_partitions: int, route: KeyRoute,
                 boundaries: np.ndarray):
        require(num_partitions >= 1, "num_partitions", num_partitions,
                "must be >= 1")
        self.num_partitions = int(num_partitions)
        self.key_route = route
        bounds = np.asarray(boundaries, dtype=np.uint32).reshape(-1)
        require(bounds.shape[0] == self.num_partitions - 1,
                "boundaries", bounds.shape[0],
                f"must supply num_partitions-1 = "
                f"{self.num_partitions - 1} internal boundaries")
        require(bool(np.all(bounds[1:] >= bounds[:-1])),
                "boundaries", bounds.tolist(),
                "must be ascending (non-overlapping ranges)")
        self._bounds = bounds

    def boundaries(self) -> np.ndarray:
        return self._bounds

    def route(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.uint32)
        return self.key_route.routed(keys, np.zeros(keys.shape, np.uint32))

    def partition_of64(self, keys: np.ndarray,
                       ids: np.ndarray) -> np.ndarray:
        routed = self.key_route.routed(keys, ids)
        return np.searchsorted(self._bounds, routed,
                               side="right").astype(np.int64)


class _ConcatSink(PartitionReducer):
    """Pass-through sink for a partition headed into another round: no
    merge — fragments are re-encoded in arrival order. Used only with
    the scheduler's sequential drain (ReduceOp.sequential_partition), so
    arrival order is source order: deterministic bytes at any
    parallelism. The staged object is a valid records object whose body
    is NOT sorted; the child round re-sorts it from scratch."""

    deferred_part0 = False

    def __init__(self, n_total: int, payload_words: int):
        self._n = int(n_total)
        self._pw = int(payload_words)

    def begin(self) -> bytes:
        return rec.encode_header(self._n, self._pw)

    def consume(self, frags, *, final: bool) -> bytes:
        return b"".join(rec.encode_body(k, i, p)
                        for k, i, p, _k64 in frags if k.size)


class RedirectReduceOp(ReduceOp):
    """Wrap any ReduceOp so `redirect`ed partitions concat to staging
    keys (sequentially, one run cursor at a time) instead of merging to
    their output keys. Non-redirected partitions pass through to the
    wrapped op untouched — same sources, sink, and bytes."""

    def __init__(self, base: ReduceOp, redirect: dict[int, str],
                 num_partitions: int):
        self.base = base
        self.payload_words = base.payload_words
        self.redirect = dict(redirect)
        self.num_partitions = int(num_partitions)

    def sources(self, r: int):
        return self.base.sources(r)

    def output_key(self, r: int) -> str:
        key = self.redirect.get(r)
        return key if key is not None else self.base.output_key(r)

    def output_metadata(self, r: int, n_total: int) -> dict:
        return self.base.output_metadata(r, n_total)

    def open(self, r: int, n_total: int) -> PartitionReducer:
        if r in self.redirect:
            return _ConcatSink(n_total, self.payload_words)
        return self.base.open(r, n_total)

    # Scheduler hooks (see shuffle/api.ReduceOp): redirected partitions
    # drain one run at a time, so when EVERY partition is redirected the
    # budget preflight only needs one run's chunk per slot.
    def sequential_partition(self, r: int) -> bool:
        return r in self.redirect

    def feasibility_runs(self, num_tasks: int) -> int:
        return (1 if len(self.redirect) >= self.num_partitions
                else num_tasks)


class SubrangeSortMapOp(MapOp):
    """Host-side map op for a recursive round: ranged-GET chunks of the
    staged (unsorted) parent partition, stable-sort each by packed
    (key << 32 | id), spill one run per task with reducer offsets at the
    routed boundaries. No device mesh — a child round is at most a few
    multiples of the reduce budget by construction, and its spill
    offsets are exactly as deterministic as the device path's."""

    spill_objects_per_task = 1

    def __init__(self, plan, partitioner: SubrangePartitioner):
        self.plan = plan
        self.partitioner = partitioner
        self.spill_offsets: dict[tuple[int, int], np.ndarray] = {}
        self.tasks: list[tuple[str, int, int]] = []

    def plan_tasks(self, store: StoreBackend, bucket: str) -> int:
        plan = self.plan
        rb = plan.record_bytes
        inputs = store.list_objects(bucket, plan.input_prefix)
        if not inputs:
            raise ValueError(
                f"input_prefix={plan.input_prefix!r}: no staged round input")
        self.tasks = []
        total = biggest = 0
        for m in inputs:
            n = (m.size - rec.HEADER_BYTES) // rb
            total += n
            for lo in range(0, n, plan.records_per_wave):
                hi = min(lo + plan.records_per_wave, n)
                self.tasks.append((m.key, lo, hi))
                biggest = max(biggest, hi - lo)
        self.total_records = total
        self.working_set_records = biggest
        return len(self.tasks)

    def load(self, store: StoreBackend, bucket: str, task: int):
        key, lo, hi = self.tasks[task]
        start, length = rec.body_range(lo, hi - lo, self.plan.payload_words)
        body = store.get_range(bucket, key, start, length)
        return rec.decode_body(body, self.plan.payload_words)

    def spill_keys(self, task: int) -> list[str]:
        return [f"{self.plan.spill_prefix}task-{task:04d}"]

    def process(self, store: StoreBackend, bucket: str, task: int, data, *,
                spiller, timeline, tag) -> None:
        keys, ids, payload = data
        t0 = time.perf_counter()
        k64 = (keys.astype(np.uint64) << np.uint64(32)) | ids.astype(
            np.uint64)
        order = np.argsort(k64, kind="stable")
        sk, si = keys[order], ids[order]
        sp = None if payload is None else payload[order]
        # routed is monotone in k64, so it is ascending over the sorted
        # run: offsets[j] = #{routed < bound_j}, the device kernel's
        # exact contract (kernels/range_partition).
        routed = self.partitioner.key_route.routed(sk, si)
        internal = np.searchsorted(routed, self.partitioner.boundaries(),
                                   side="left")
        offsets = np.concatenate(([0], internal, [sk.size])).astype(np.int64)
        self.spill_offsets[(task, 0)] = offsets
        encoded = rec.encode_records(sk, si, sp)
        timeline.add("map.compute", t0, worker=tag)
        t_spill = time.perf_counter()
        spiller.submit(_rt.timed_put, timeline, tag, store, bucket,
                       self.spill_keys(task)[0], encoded, {
                           "records": int(sk.size),
                           "task": task,
                           "reducer_offsets": [int(o) for o in offsets],
                       })
        timeline.add("map.spill_wait", t_spill, worker=tag)


class SubrangeMergeReduceOp(ReduceOp):
    """Reduce side of a recursive round: sub-partition r streams its
    slice of every task's run through the standard k-way merge sink into
    `<output_prefix>sub-NNNNN`."""

    def __init__(self, plan, map_op: SubrangeSortMapOp):
        self.plan = plan
        self.map_op = map_op
        self.payload_words = plan.payload_words

    def sources(self, r: int):
        slices, n_total = [], 0
        for t in range(len(self.map_op.tasks)):
            offs = self.map_op.spill_offsets[(t, 0)]
            lo, hi = int(offs[r]), int(offs[r + 1])
            if hi > lo:
                slices.append((self.map_op.spill_keys(t)[0], lo, hi))
                n_total += hi - lo
        return slices, n_total

    def output_key(self, r: int) -> str:
        return f"{self.plan.output_prefix}sub-{r:05d}"

    def output_metadata(self, r: int, n_total: int) -> dict:
        return {"records": n_total, "reducer": r}

    def open(self, r: int, n_total: int) -> PartitionReducer:
        from repro.shuffle.sort import _SortMergeSink

        return _SortMergeSink(n_total, self.payload_words)


@dataclasses.dataclass
class RecursiveSortReport:
    """Aggregate of a recursive_sort run: the per-round reports plus the
    recursion decisions, for assertions and the skew benchmark."""

    rounds: list  # (depth, path, ShuffleReport | ClusterShuffleReport)
    sample: KeySample | None
    recursed: list[str]  # partition paths that got their own round
    restaged: list[str]  # subset recursed by OBSERVATION (sampler miss)
    output_objects: int

    @property
    def num_rounds(self) -> int:
        return max((d for d, _, _ in self.rounds), default=0)

    @property
    def report(self):
        """The round-1 report (top-level phase timings / store traffic)."""
        return self.rounds[0][2]


@dataclasses.dataclass(frozen=True)
class _Item:
    """One staged partition awaiting its own round."""

    path: str  # e.g. "part-00003" or "part-00003/sub-00001"
    in_prefix: str  # staging dir holding the partition's bytes
    lo64: int  # packed (key<<32|id) range covered, [lo64, hi64)
    hi64: int
    records: int
    depth: int


def _clear_prefix(store: StoreBackend, bucket: str, prefix: str) -> None:
    for meta in store.list_objects(bucket, prefix):
        store.delete(bucket, meta.key)


def _run_job(job: ShuffleJob, *, workers, cluster, worker_list, fleet):
    return job.run(workers, cluster=cluster, worker_list=worker_list,
                   fleet=fleet)


def recursive_sort(store: StoreBackend, bucket: str, *, mesh, axis_names,
                   plan, workers: int = 0, cluster=None,
                   worker_list: Sequence | None = None, fleet=None,
                   tracer: Tracer | None = None) -> RecursiveSortReport:
    """Skew-adaptive, recursively composed sort of plan.input_prefix into
    plan.output_prefix.

    With plan.sample_fraction > 0, a sampling pre-pass sets the
    partition boundaries (and predicts which partitions to redirect);
    with plan.max_rounds > 1, partitions whose merged size would exceed
    plan.reduce_memory_budget_bytes are re-shuffled as child ShuffleJobs
    (see the module docstring). With both knobs at their defaults this
    is exactly shuffle/sort.sort_shuffle_job. Execution args
    (workers/cluster/worker_list/fleet) pass through to every round's
    job.run; validate the final output with
    data/valsort.validate_from_store on plan.output_prefix, unchanged.
    """
    from repro.shuffle.sort import (DeviceMergeReduceOp, MergeReduceOp,
                                    SortMapOp)

    plan.validate()
    axis = tuple([axis_names] if isinstance(axis_names, str) else axis_names)
    w = int(math.prod(mesh.shape[a] for a in axis))
    parts = w * plan.reducers_per_worker
    tracer = tracer if tracer is not None else Tracer(job="recursive-sort")
    budget = plan.reduce_memory_budget_bytes
    rb = plan.record_bytes
    rprefix = recurse_prefix(plan)
    _clear_prefix(store, bucket, rprefix)

    # --- sample phase (its own traced/billed phase, see job.py) ---------
    samp = None
    bounds = None
    est = None
    if plan.sample_fraction > 0:
        samp = sample_boundaries(
            store, bucket, input_prefix=plan.input_prefix,
            payload_words=plan.payload_words,
            sample_fraction=plan.sample_fraction, parts=parts,
            tracer=tracer)
        bounds = samp.boundaries
        est = samp.partition_records()

    # --- round 1: the device-path job, with predicted redirects ---------
    def stage_key(path: str) -> str:
        return f"{rprefix}{path}/in/part-00000"

    def path_of(j: int) -> str:
        return f"part-{j:05d}"

    redirect: dict[int, str] = {}
    if budget > 0 and plan.max_rounds > 1 and est is not None:
        redirect = {j: stage_key(path_of(j)) for j in range(parts)
                    if int(est[j]) * rb > budget}
        for j in sorted(redirect):
            tracer.instant("recursive.redirect", ctx=tracer.root,
                           path=path_of(j), predicted_records=int(est[j]))

    map_op = SortMapOp(plan, mesh, axis_names, boundaries=bounds)
    base_op = (DeviceMergeReduceOp(plan, map_op)
               if getattr(plan, "reduce_merge_impl", "numpy") == "device"
               else MergeReduceOp(plan, map_op))
    reduce_op = RedirectReduceOp(base_op, redirect, parts)
    partitioner = RangePartitioner(parts, boundaries=bounds)
    job = ShuffleJob(store, bucket, plan=plan, map_op=map_op,
                     reduce_op=reduce_op, partitioner=partitioner,
                     tracer=tracer)
    rep1 = _run_job(job, workers=workers, cluster=cluster,
                    worker_list=worker_list, fleet=fleet)
    tracer.instant("recursive.round", ctx=tracer.root, depth=1, path="",
                   partitions=parts, redirected=len(redirect))
    rounds: list = [(1, "", rep1)]
    recursed: list[str] = []
    restaged: list[str] = []

    # Key range of each round-1 partition (for child routing).
    full_bounds = np.asarray(partitioner.boundaries(), np.uint64)
    key_lo = np.concatenate(([0], full_bounds))
    key_hi = np.concatenate((full_bounds, [1 << 32]))

    frontier: list[_Item] = []

    def stage_item(path: str, key: str, lo64: int, hi64: int,
                   depth: int) -> None:
        n = (store.head(bucket, key).size - rec.HEADER_BYTES) // rb
        if n == 0:
            store.delete(bucket, key)
            return
        recursed.append(path)
        frontier.append(_Item(path=path, in_prefix=f"{rprefix}{path}/in/",
                              lo64=lo64, hi64=hi64, records=n, depth=depth))

    def observe_and_restage(out_key: str, path: str, lo64: int, hi64: int,
                            depth: int) -> None:
        """A committed (merged) output the round measured oversized:
        copy it to staging, drop the original, recurse. The copy is the
        price of a sampler miss — predicted redirects never pay it."""
        try:
            meta = store.head(bucket, out_key)
        except KeyError:
            return  # empty partitions may legitimately not exist
        if (meta.size - rec.HEADER_BYTES) <= budget:
            return
        skey = stage_key(path)
        store.put(bucket, skey, store.get(bucket, out_key),
                  metadata={"restaged_from": out_key})
        store.delete(bucket, out_key)
        restaged.append(path)
        tracer.instant("recursive.restage", ctx=tracer.root, path=path,
                       nbytes=meta.size)
        stage_item(path, skey, lo64, hi64, depth)

    for j in sorted(redirect):
        stage_item(path_of(j), redirect[j], int(key_lo[j]) << 32,
                   int(key_hi[j]) << 32, depth=2)
    if budget > 0 and plan.max_rounds > 1:
        for j in range(parts):
            if j in redirect:
                continue
            observe_and_restage(base_op.output_key(j), path_of(j),
                                int(key_lo[j]) << 32, int(key_hi[j]) << 32,
                                depth=2)

    # --- rounds 2..max_rounds: child jobs over the staged partitions ----
    while frontier:
        item = frontier.pop(0)
        deeper = item.depth < plan.max_rounds
        child_plan = dataclasses.replace(
            plan,
            input_prefix=item.in_prefix,
            spill_prefix=f"{rprefix}{item.path}/spill/",
            output_prefix=f"{plan.output_prefix}{item.path}/",
        )
        route = KeyRoute(lo64=item.lo64, hi64=item.hi64)
        # Target each sub-partition at ~half the budget so a modest
        # estimate error doesn't immediately trigger another round.
        sub_parts = max(2, -(-item.records * rb // max(budget // 2, 1)))
        cest = None
        if plan.sample_fraction > 0:
            csamp = sample_boundaries(
                store, bucket, input_prefix=child_plan.input_prefix,
                payload_words=plan.payload_words,
                sample_fraction=plan.sample_fraction, parts=sub_parts,
                tracer=tracer, route=route.routed)
            cbounds = csamp.boundaries
            cest = csamp.partition_records()
        else:
            cbounds = route.equal_bounds(sub_parts)
        credirect: dict[int, str] = {}
        if deeper and cest is not None:
            credirect = {
                q: stage_key(f"{item.path}/sub-{q:05d}")
                for q in range(sub_parts) if int(cest[q]) * rb > budget}
        sub_partitioner = SubrangePartitioner(sub_parts, route, cbounds)
        cmap = SubrangeSortMapOp(child_plan, sub_partitioner)
        creduce = RedirectReduceOp(SubrangeMergeReduceOp(child_plan, cmap),
                                   credirect, sub_parts)
        child = ShuffleJob(store, bucket, plan=child_plan, map_op=cmap,
                           reduce_op=creduce, partitioner=sub_partitioner,
                           tracer=tracer)
        crep = _run_job(child, workers=workers, cluster=cluster,
                        worker_list=worker_list, fleet=fleet)
        tracer.instant("recursive.round", ctx=tracer.root, depth=item.depth,
                       path=item.path, partitions=sub_parts,
                       redirected=len(credirect))
        rounds.append((item.depth, item.path, crep))
        for q in sorted(credirect):
            lo64, hi64 = route.sub_range64(cbounds, q)
            stage_item(f"{item.path}/sub-{q:05d}", credirect[q], lo64, hi64,
                       depth=item.depth + 1)
        if deeper:
            for q in range(sub_parts):
                if q in credirect:
                    continue
                lo64, hi64 = route.sub_range64(cbounds, q)
                observe_and_restage(
                    f"{child_plan.output_prefix}sub-{q:05d}",
                    f"{item.path}/sub-{q:05d}", lo64, hi64,
                    depth=item.depth + 1)

    _clear_prefix(store, bucket, rprefix)
    return RecursiveSortReport(
        rounds=rounds, sample=samp, recursed=recursed, restaged=restaged,
        output_objects=len(store.list_objects(bucket, plan.output_prefix)),
    )


__all__ = ["KeyRoute", "RecursiveSortReport", "RedirectReduceOp",
           "SubrangeMergeReduceOp", "SubrangePartitioner",
           "SubrangeSortMapOp", "recursive_sort", "recurse_prefix"]
