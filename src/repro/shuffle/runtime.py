"""The shuffle engine room: staging loops, streaming merges, governance.

Everything here used to live inside core/external_sort.py and was
sort-flavoured by accident, not by necessity: span timelines, job-wide
cancellation, the adaptive reduce-memory governor, bounded run cursors,
the reduce scheduler, and the prefetched map loop are workload-agnostic
once the workload-specific decisions are pushed behind the MapOp /
ReduceOp / PartitionReducer protocols (shuffle/api.py). The sort keeps
its exact byte behaviour — SortMapOp / MergeReduceOp (shuffle/sort.py)
wrap the same WaveSorter / k-way-merge bodies this code used to call
directly — and any other workload (shuffle/groupby.py) gets the same
staging, budget, and fault-recovery machinery for free.

Memory contract (the reduce side): up to `slots` streaming reducers run
concurrently, each holding at most `runs x chunk` decoded bytes, where
chunks are granted by the AdaptiveBudgetGovernor out of the plan's
global `reduce_memory_budget_bytes` — see the governor docstring for
the provable bound. Encoded output parts being sliced/uploaded sit on
top (~(1 + max_inflight_writes) x part bytes per active reducer).

Observability: PhaseTimeline's raw span list is capped at `max_spans`
(default 4096, a constructor knob) — per-phase totals stay exact past
the cap and the report's `spans_dropped` (surfaced by ShuffleReport and
ClusterShuffleReport alike) counts the overflow, so a huge run degrades
to aggregates instead of hoarding memory. Wire a `sink` (usually
obs/events.Tracer.timeline_sink()) to forward every span into the
unified event log as it is recorded; task execution binds an
obs TraceContext (phase/task/worker) around each map task and reduce
partition so store requests issued on behalf of a task — including
writes handed to staging pools — are attributed to it.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable

import numpy as np

from repro.io import records as rec
from repro.io import staging
from repro.io.backends import RetryableError, StoreBackend
from repro.obs.context import (TraceContext, bind_context, current_context,
                               use_context)

from repro.shuffle.api import MapOp, ReduceOp, require

# Attempt-unique suffix for governor/peak accounting keys: speculative
# duplicates of one partition run concurrently and must each hold their
# own grant — keying by partition id alone would leak or double-free.
_ATTEMPT_SEQ = itertools.count()


def _task_context(phase: str, task, tag_prefix: str) -> TraceContext:
    """The TraceContext for one task: narrows the ambient context (the
    cluster driver binds job/worker) or starts fresh on the single host."""
    base = current_context() or TraceContext(job="job")
    worker = tag_prefix.rstrip("/") or base.worker or "host"
    return base.with_phase(phase).with_task(task).with_worker(worker)


@dataclasses.dataclass(frozen=True)
class Span:
    """One recorded phase interval, seconds relative to the job start."""

    phase: str  # e.g. "map.compute", "reduce.upload"
    start: float
    end: float
    worker: str = ""  # "w3" map task / "r12" reducer tag

    @property
    def seconds(self) -> float:
        return self.end - self.start


class PhaseTimeline:
    """Thread-safe span recorder for the per-phase timeline.

    Aggregate per-phase totals are exact; the raw span list is capped at
    `max_spans` (oldest kept) so a huge run cannot hoard memory — the
    report's `spans_dropped` says how many were dropped. Because spans from overlapping
    threads both count wall time, a phase total larger than the enclosing
    stage's wall time is *measured overlap*, which is the point.
    """

    def __init__(self, origin: float, *, max_spans: int = 4096,
                 sink=None):
        self._origin = origin
        self._lock = threading.Lock()
        self._totals: dict[str, float] = {}
        self._spans: list[Span] = []
        self._max = int(max_spans)
        self._sink = sink  # callable(phase, abs_start, abs_end, worker_tag)
        self.dropped = 0

    def add(self, phase: str, start: float, end: float | None = None,
            *, worker: str = "") -> None:
        end = time.perf_counter() if end is None else end
        span = Span(phase, start - self._origin, end - self._origin, worker)
        with self._lock:
            self._totals[phase] = self._totals.get(phase, 0.0) + span.seconds
            if len(self._spans) < self._max:
                self._spans.append(span)
            else:
                self.dropped += 1
        if self._sink is not None:
            # Outside the lock: the sink (obs Tracer) has its own, and
            # it receives ABSOLUTE times — its clock origin may differ.
            self._sink(phase, start, end, worker)

    @contextlib.contextmanager
    def span(self, phase: str, worker: str = ""):
        t = time.perf_counter()
        try:
            yield
        finally:
            self.add(phase, t, worker=worker)

    def totals(self) -> dict[str, float]:
        with self._lock:
            return dict(self._totals)

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)


class PeakTracker:
    """Thread-safe global peak of summed per-reducer buffered merge bytes —
    the measurement behind the reduce_memory_budget_bytes guarantee."""

    def __init__(self):
        self._lock = threading.Lock()
        self._per: dict[int, int] = {}
        self._total = 0
        self.peak = 0

    def update(self, rid: int, nbytes: int) -> None:
        with self._lock:
            self._total += nbytes - self._per.get(rid, 0)
            self._per[rid] = nbytes
            if self._total > self.peak:
                self.peak = self._total

    def clear(self, rid: int) -> None:
        with self._lock:
            self._total -= self._per.pop(rid, 0)


class JobControl:
    """Job-wide cancellation + first-failure collection.

    Shared by every scheduler (and, in cluster mode, every worker) of one
    job: a real failure anywhere cancels the whole job, and the
    chronologically first exception is what the driver re-raises.
    """

    def __init__(self):
        self.cancel = threading.Event()
        self._lock = threading.Lock()
        self._first: list[BaseException] = []

    def fail(self, e: BaseException) -> None:
        with self._lock:
            if not self._first:
                self._first.append(e)
        self.cancel.set()

    @property
    def failed(self) -> bool:
        with self._lock:
            return bool(self._first)

    def raise_first(self) -> None:
        with self._lock:
            if self._first:
                raise self._first[0]


class AdaptiveBudgetGovernor:
    """Adaptive apportionment of the global reduce memory budget.

    Replaces the static active-count split: every registering reducer is
    granted the static fair share S0 = budget // slots (the floor
    reduce_chunking validates up front), and on every emit cycle it may
    `grow` its grant out of budget freed by retired reducers — so the
    tail of the reduce phase runs with bigger per-run chunks instead of
    leaving freed budget idle ("chunk sizes grow mid-merge").

    The budget bound is provable, not just measured:

      * bytes only move between the free pool and live grants under one
        lock, and the free pool never goes negative — so the sum of live
        grants never exceeds the budget;
      * a live reducer's grant (hence chunk) never shrinks — growth only
        draws from `free` beyond a reservation of S0 per not-yet-started
        partition (up to the slot count), so a late registrant never
        needs to claw back granted bytes;
      * each reducer buffers at most runs x chunk <= grant decoded bytes,
        so the measured all-reducer peak (reduce_peak_merge_bytes) is
        under the budget at every instant.

    With budget == 0 the governor is inert: every cursor just uses the
    merge_chunk_bytes cap.
    """

    def __init__(self, *, budget: int, chunk_cap: int, record_bytes: int,
                 slots: int, partitions: int, tracer=None):
        self.tracer = tracer  # obs Tracer: governor.grant_bytes histogram
        self.budget = int(budget)
        self.chunk_cap = int(chunk_cap)
        self.record_bytes = int(record_bytes)
        self.slots = max(int(slots), 1)
        self._cond = threading.Condition()
        self._free = self.budget
        self._live: dict[int, tuple[int, int]] = {}  # rid -> (runs, grant)
        # Completed rids as a SET, not a counter: a partition whose merge
        # retired but whose async commit later died (cluster worker
        # failure) is re-executed and retires AGAIN — dedup keeps the
        # unstarted-partition reservation from under-counting.
        self._done_rids: set[int] = set()
        self._partitions = int(partitions)
        self._base = self.budget // self.slots if self.budget else 0
        self.max_chunk_bytes = 0 if self.budget else self.chunk_cap

    def _chunk_of(self, runs: int, grant: int) -> int:
        return min(self.chunk_cap, grant // max(runs, 1))

    def register(self, rid: int, runs: int,
                 abort: Callable[[], bool] | None = None) -> int | None:
        """Reserve an initial grant; returns the per-run chunk in bytes.

        Blocks while the free pool cannot cover even one record per run
        (only possible transiently, while grown siblings hold surplus
        that their retirement will release). Returns None if `abort`
        turns true while waiting.
        """
        if not self.budget:
            return self.chunk_cap
        min_need = max(runs, 1) * self.record_bytes
        with self._cond:
            while self._free < min_need:
                if abort is not None and abort():
                    return None
                self._cond.wait(timeout=0.05)
            grant = max(min(self._base, runs * self.chunk_cap, self._free),
                        min_need)
            self._live[rid] = (runs, grant)
            self._free -= grant
            chunk = self._chunk_of(runs, grant)
            self.max_chunk_bytes = max(self.max_chunk_bytes, chunk)
        if self.tracer is not None:
            self.tracer.registry.observe("governor.grant_bytes", grant,
                                         event="register")
        return chunk

    def chunk_bytes(self, rid: int) -> int:
        if not self.budget:
            return self.chunk_cap
        with self._cond:
            runs, grant = self._live[rid]
            return self._chunk_of(runs, grant)

    def grow(self, rid: int) -> int:
        """Re-apportion freed budget into this reducer's grant (monotone);
        returns the current per-run chunk in bytes."""
        if not self.budget:
            return self.chunk_cap
        grew = 0
        with self._cond:
            runs, grant = self._live[rid]
            target = runs * self.chunk_cap
            if grant < target:
                # Keep S0 reserved for every partition that still has to
                # start (bounded by the free scheduler slots), so future
                # registrants are never starved by growth.
                unstarted = (self._partitions - len(self._done_rids)
                             - len(self._live))
                reserve = self._base * max(
                    0, min(self.slots - len(self._live), unstarted))
                avail = self._free - reserve
                extra = min(target - grant, avail // max(len(self._live), 1))
                if extra > 0:
                    grant += extra
                    self._live[rid] = (runs, grant)
                    self._free -= extra
                    grew = extra
            chunk = self._chunk_of(runs, grant)
            self.max_chunk_bytes = max(self.max_chunk_bytes, chunk)
        if grew and self.tracer is not None:
            self.tracer.registry.observe("governor.grant_bytes", grant,
                                         event="grow")
        return chunk

    def retire(self, rid: int, *, completed: bool = True) -> None:
        """Release the grant back to the free pool (waking any waiting
        registrant); `completed=False` marks a failed reducer whose
        partition will be re-executed (cluster failure recovery)."""
        if not self.budget:
            return
        with self._cond:
            entry = self._live.pop(rid, None)
            if entry is not None:
                self._free += entry[1]
            if completed:
                # Attempt keys are (partition, attempt) tuples when the
                # scheduler may run duplicate attempts (speculation);
                # done-accounting is per PARTITION either way.
                self._done_rids.add(rid[0] if isinstance(rid, tuple) else rid)
            self._cond.notify_all()


def reduce_chunking(plan, runs: int, active: int) -> tuple[int, int]:
    """(chunk_records, chunk_bytes) per run under the global budget.

    This is the STATIC fair split — the governor's starting point and the
    up-front feasibility check: with a budget, each of the `active`
    concurrent reducers gets an equal share, split over its `runs`
    cursors and capped at merge_chunk_bytes; the all-reducer total
    active x runs x chunk therefore never exceeds the budget. Without
    one, every cursor buffers merge_chunk_bytes. At runtime the adaptive
    governor only ever grants MORE than this (never less), drawing on
    budget freed by retired reducers.
    """
    rb = plan.record_bytes
    require(plan.merge_chunk_bytes >= rb, "merge_chunk_bytes",
            plan.merge_chunk_bytes,
            f"must hold at least one {rb}-byte record, else the "
            "reduce-memory bound cannot be met")
    chunk_bytes = plan.merge_chunk_bytes
    if plan.reduce_memory_budget_bytes:
        share = plan.reduce_memory_budget_bytes // max(active, 1)
        chunk_bytes = min(chunk_bytes, share // max(runs, 1))
        require(chunk_bytes >= rb, "reduce_memory_budget_bytes",
                plan.reduce_memory_budget_bytes,
                f"cannot give each of {active} concurrent reducers one "
                f"{rb}-byte record per run ({runs} runs each) — raise the "
                "budget or lower parallel_reducers")
    return chunk_bytes // rb, chunk_bytes


class RunCursor:
    """Bounded window over one spilled run's partition slice.

    Holds at most `chunk_records` decoded records at a time; `refill`
    issues one ranged GET for the next chunk, `take_upto` consumes the
    buffered prefix that is safe to emit (every record <= bound). The
    chunk size may be raised mid-stream (`set_chunk`) when the adaptive
    governor re-apportions budget freed by retired reducers.
    """

    __slots__ = ("_store", "_bucket", "_key", "_hi", "_next", "_chunk",
                 "_pw", "k64", "keys", "ids", "payload")

    def __init__(self, store, bucket, key, lo, hi, payload_words, chunk_records):
        self._store = store
        self._bucket = bucket
        self._key = key
        self._next = int(lo)
        self._hi = int(hi)
        self._chunk = int(chunk_records)
        self._pw = int(payload_words)
        self.keys = np.empty((0,), np.uint32)
        self.ids = np.empty((0,), np.uint32)
        self.payload = None
        self.k64 = np.empty((0,), np.uint64)

    @property
    def has_more_remote(self) -> bool:
        return self._next < self._hi

    @property
    def exhausted(self) -> bool:
        return not self.has_more_remote and self.k64.size == 0

    @property
    def buffered_bytes(self) -> int:
        return self.k64.size * rec.record_bytes(self._pw)

    def set_chunk(self, chunk_records: int) -> None:
        self._chunk = int(chunk_records)

    def refill(self) -> None:
        n = min(self._chunk, self._hi - self._next)
        start, length = rec.body_range(self._next, n, self._pw)
        body = self._store.get_range(self._bucket, self._key, start, length)
        self._next += n
        k, i, p = rec.decode_body(body, self._pw)
        self.keys, self.ids, self.payload = k, i, p
        self.k64 = k.astype(np.uint64) << np.uint64(32) | i.astype(np.uint64)

    def take_upto(self, bound):
        """Consume and return the (keys, ids, payload, k64) prefix with
        k64 <= bound; bound=None consumes everything buffered."""
        cut = self.k64.size if bound is None else int(
            np.searchsorted(self.k64, bound, side="right"))
        out = (self.keys[:cut], self.ids[:cut],
               None if self.payload is None else self.payload[:cut],
               self.k64[:cut])
        self.keys, self.ids = self.keys[cut:], self.ids[cut:]
        self.payload = None if self.payload is None else self.payload[cut:]
        self.k64 = self.k64[cut:]
        return out


def merge_fragments(frags, payload_words: int):
    """Merge already-sorted fragments (one per run) into one sorted batch.

    A plain stable argsort over the concatenated packed keys
    (key<<32|id) is the k-way merge of the emit window — small
    (≤ runs x chunk records) by construction, which is the whole point
    of the streaming reduce. Packed keys need NOT be unique across
    fragments (the group-by's (key, count) records collide routinely):
    ties keep a stable, deterministic order — fragment list order, then
    within-fragment order — so output bytes are reproducible, but a
    consumer must not assume distinct packed keys. The sort workload's
    gensort ids happen to be unique, which is what makes its merge
    windows totally ordered.
    """
    frags = [f for f in frags if f[3].size]
    if not frags:
        empty = np.empty((0,), np.uint32)
        pw = int(payload_words)
        return empty, empty, (np.empty((0, pw), np.uint32) if pw else None)
    if len(frags) == 1:
        k, i, p, _ = frags[0]
        return k, i, p
    # Fast path: the live fragments do not interleave (each ends at or
    # below the next one's start) — common at the tail of skewed
    # partitions, where a single run is left emitting. Concatenation IS
    # the stable merge: a boundary tie keeps fragment order, exactly
    # what the stable argsort below would produce, so the bytes are
    # identical and the O(n log n) re-sort is skipped.
    if all(frags[i][3][-1] <= frags[i + 1][3][0]
           for i in range(len(frags) - 1)):
        keys = np.concatenate([f[0] for f in frags])
        ids = np.concatenate([f[1] for f in frags])
        payload = (np.concatenate([f[2] for f in frags])
                   if payload_words else None)
        return keys, ids, payload
    k64 = np.concatenate([f[3] for f in frags])
    order = np.argsort(k64, kind="stable")
    keys = np.concatenate([f[0] for f in frags])[order]
    ids = np.concatenate([f[1] for f in frags])[order]
    payload = None
    if payload_words:
        payload = np.concatenate([f[2] for f in frags])[order]
    return keys, ids, payload


class SiblingFailed(Exception):
    """Internal: this reducer was cancelled because another one failed."""


class AttemptLost(Exception):
    """Internal: this attempt lost a speculative race — another attempt
    of the same task already committed durably, so finishing this one is
    pure wasted wall-clock (the phase join would wait for it). Raised
    from the cooperative abandonment checks (the map read gate, the
    reduce merge-window poll) and handled as a clean abort: the attempt
    unwinds through the normal cleanup path (multipart abort, grant
    retirement) and its scheduler keeps running."""


class _AbandonGatedReads:
    """Read-path store proxy for a speculative map attempt: every GET
    (and every get_chunks chunk) first consults the commit gate, and
    once another attempt of this task has durably committed the next
    check raises AttemptLost — the loser stops fetching at the next
    chunk boundary instead of dragging the phase join to its own finish
    line. Write paths are deliberately NOT gated: map spill bytes are
    deterministic functions of (task, plan, input), so a racing
    duplicate write is byte-identical and harmless — it is the chunked
    fetch loop that burns wall-clock on a straggler."""

    def __init__(self, inner, may_commit: Callable[[], bool]):
        self._inner = inner
        self._may_commit = may_commit

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def _check(self) -> None:
        if not self._may_commit():
            raise AttemptLost()

    def get(self, *args, **kwargs):
        self._check()
        return self._inner.get(*args, **kwargs)

    def get_range(self, *args, **kwargs):
        self._check()
        return self._inner.get_range(*args, **kwargs)

    def get_chunks(self, *args, **kwargs):
        it = self._inner.get_chunks(*args, **kwargs)
        while True:
            self._check()
            try:
                chunk = next(it)
            except StopIteration:
                return
            yield chunk


def timed_part(timeline: PhaseTimeline, tag: str, mp, index: int,
               data: bytes) -> None:
    """Background part upload, recorded as a reduce.upload span."""
    t = time.perf_counter()
    mp.put_part(index, data)
    timeline.add("reduce.upload", t, worker=tag)


def timed_put(timeline: PhaseTimeline, tag: str, store, bucket: str,
              key: str, data: bytes, metadata: dict) -> None:
    """Background spill put, recorded as a map.spill span."""
    t = time.perf_counter()
    store.put(bucket, key, data, metadata=metadata)
    timeline.add("map.spill", t, worker=tag)


def finalize_session(timeline: PhaseTimeline, tag: str,
                     uploader: staging.AsyncWriter, mp,
                     on_done: Callable[[], None] | None = None, *,
                     commit_gate: Callable[[], bool] | None = None) -> None:
    """Background session finisher: wait for the partition's in-flight
    parts, then commit — or abort on any failure (a truncated commit
    would carry a self-consistent CRC etag IntegrityError can't catch).
    Running this off the merge thread is what lets a reducer's scheduler
    slot free while its tail uploads still stream (partition r's uploads
    overlap partition r+active's merge even at parallel_reducers=1).
    `on_done` fires only after the commit succeeds — the durability
    confirmation the cluster driver uses to decide what a dead worker
    still owed.

    `commit_gate` is the speculation loser-abort point: consulted after
    all parts land and immediately before the commit, a False answer
    (another attempt of this task already committed durably) aborts the
    session instead — no double commit, no on_done."""
    t = time.perf_counter()
    try:
        uploader.close()  # waits all parts; re-raises the first failure
    except BaseException:
        mp.abort()
        raise
    try:
        if commit_gate is not None and not commit_gate():
            mp.abort()
            return
        mp.complete()
    except BaseException:
        mp.abort()
        raise
    finally:
        timeline.add("reduce.upload_wait", t, worker=tag)
    if on_done is not None:
        on_done()


@dataclasses.dataclass
class ReduceShared:
    """Job-level shared state for one shuffle's reduce pass — shared
    across every ReduceScheduler (one on a single host, one per cluster
    worker), so the budget governor, peak accounting, cancellation, and
    timeline stay global while the schedulers stay per-worker. The
    workload enters only through `reduce_op`."""

    plan: "object"  # any dataflow plan (see api.validate_dataflow_plan)
    bucket: str
    reduce_op: ReduceOp
    governor: AdaptiveBudgetGovernor
    timeline: PhaseTimeline
    peak: PeakTracker
    control: JobControl


class ReduceScheduler:
    """One host's (or one emulated cluster worker's) reduce scheduler.

    Pulls partition ids from `pop_next` and runs up to `width` streaming
    reducers concurrently against `store`, sharing the job-level
    governor/peak/cancellation through `shared` and delegating the data
    to `shared.reduce_op` (sources + PartitionReducer sink). Failure
    taxonomy:

      * exceptions of a type in `fatal` mean THIS scheduler's worker died
        (shuffle/executor.WorkerFailure): the scheduler unwinds and
        re-raises so the cluster driver can re-execute unconfirmed
        partitions on survivors — the job keeps going;
      * any other exception is a job failure: it is recorded on
        shared.control (which cancels every scheduler) and the driver
        re-raises it after the barrier.

    A partition only counts as done (`on_done`) after its multipart
    session COMMITS — merge completion is not durability.
    """

    def __init__(self, store: StoreBackend, shared: ReduceShared, *,
                 width: int, runs_hint: int = 2, fatal: tuple = (),
                 tag_prefix: str = "", requeue: tuple = (),
                 on_requeue: Callable[[int, BaseException], bool] | None = None,
                 commit_gate: Callable[[int], bool] | None = None,
                 gate_poll: bool = False):
        self.store = store
        self.shared = shared
        self.width = max(int(width), 1)
        self.runs_hint = max(int(runs_hint), 1)
        self.fatal = tuple(fatal)
        self.tag_prefix = tag_prefix
        # Elastic-driver hooks. `requeue` exception types mean the
        # partition's INPUT vanished under it (correlated spill loss):
        # the attempt aborted cleanly, the scheduler stays alive, and
        # `on_requeue(r, exc)` decides whether the driver can recover
        # (True: hand the partition back for a later attempt) or the
        # loss is unexplained (False: job failure). `commit_gate(r)` is
        # threaded to finalize_session as the speculation loser-abort.
        # With `gate_poll`, the gate is ALSO polled between merge
        # windows so a losing attempt abandons mid-merge (AttemptLost)
        # instead of streaming its whole partition first — only enable
        # it when the gate is a cheap in-process predicate (the process
        # worker's gate is a parent RPC and stays commit-time-only).
        self.requeue = tuple(requeue)
        self.on_requeue = on_requeue
        self.commit_gate = commit_gate
        self.gate_poll = gate_poll

    def run(self, pop_next: Callable[[], int | None],
            on_done: Callable[[int], None] | None = None) -> None:
        """Drain partitions until the queue is empty, the job is
        cancelled, or this scheduler's worker dies (re-raised)."""
        shared = self.shared
        plan = shared.plan
        refill_pool = ThreadPoolExecutor(
            max_workers=min(16, max(2, self.runs_hint * self.width)),
            thread_name_prefix="reduce-refill")
        finishers = staging.AsyncWriter(
            max(plan.max_inflight_writes, self.width), max_workers=self.width,
            thread_name_prefix="reduce-finish")
        dead_lock = threading.Lock()
        dead: list[BaseException] = []
        dead_evt = threading.Event()

        def loop() -> None:
            while not (shared.control.cancel.is_set() or dead_evt.is_set()):
                try:
                    r = pop_next()
                except self.fatal as e:  # the worker died at the queue
                    with dead_lock:
                        dead.append(e)
                    dead_evt.set()
                    return
                if r is None:
                    return
                try:
                    self._reduce_one(r, refill_pool, finishers, on_done)
                except SiblingFailed:
                    pass  # aborted cleanly; the root cause is recorded
                except AttemptLost:
                    continue  # lost a speculative race; the winner committed
                except self.fatal as e:  # worker death: stop this scheduler
                    with dead_lock:
                        dead.append(e)
                    dead_evt.set()
                    return
                except self.requeue as e:  # input lost mid-merge
                    handled = False
                    if self.on_requeue is not None:
                        try:
                            handled = bool(self.on_requeue(r, e))
                        except BaseException as e2:
                            shared.control.fail(e2)
                            return
                    if not handled:
                        shared.control.fail(e)
                        return
                    continue  # the attempt aborted; the driver re-plans
                except BaseException as e:  # real failure: cancel the job
                    shared.control.fail(e)
                    return

        threads = [threading.Thread(target=loop, name=f"reduce-merge-{i}")
                   for i in range(self.width)]
        try:
            for t in threads:
                t.start()
        finally:
            for t in threads:
                t.join()
            refill_pool.shutdown(wait=True)
            try:
                finishers.close()  # re-raises the first finisher failure
            except self.fatal as e:
                # Death during commit: those partitions never confirmed,
                # so the cluster driver will re-execute them.
                with dead_lock:
                    dead.append(e)
            except BaseException as e:
                shared.control.fail(e)
        if dead:
            raise dead[0]

    # -- internals ---------------------------------------------------------

    def _reduce_one(self, r: int, refill_pool, finishers,
                    on_done: Callable[[int], None] | None) -> None:
        # The whole partition body runs under its TraceContext: the
        # ranged GETs (inline or via the refill pool), part uploads and
        # the finisher commit (captured by AsyncWriter.submit) are all
        # attributed to reduce task r on this worker.
        with use_context(_task_context("reduce", f"r{r}", self.tag_prefix)):
            self._reduce_one_inner(r, refill_pool, finishers, on_done)

    def _reduce_one_inner(self, r: int, refill_pool, finishers,
                          on_done: Callable[[int], None] | None) -> None:
        shared = self.shared
        plan = shared.plan
        op = shared.reduce_op
        store = self.store
        timeline = shared.timeline
        governor = shared.governor
        pw = op.payload_words
        rb = rec.record_bytes(pw)
        part_bytes = plan.output_part_records * rb
        tag = f"{self.tag_prefix}r{r}"
        slices, n_total = op.sources(r)
        registered = bool(slices)
        chunk_records = 0
        # Optional ReduceOp extension (shuffle/recursive's redirected
        # partitions): a sequential partition's sink concatenates runs in
        # source order instead of merging them, so its cursors drain ONE
        # AT A TIME — the budget grant covers a single run's chunk no
        # matter how many map tasks spilled, which is what removes the
        # reduce fan-in ceiling for partitions headed into another
        # shuffle round.
        seq_fn = getattr(op, "sequential_partition", None)
        sequential = bool(seq_fn(r)) if callable(seq_fn) else False
        # Grant/peak accounting keys by ATTEMPT, not partition: under
        # speculation two attempts of one partition can merge at once,
        # and each must hold (and release) its own budget grant for the
        # governor's bound to stay provable.
        akey = (r, next(_ATTEMPT_SEQ))
        if registered:
            chunk = governor.register(
                akey, 1 if sequential else len(slices),
                abort=shared.control.cancel.is_set)
            if chunk is None:
                raise SiblingFailed()
            chunk_records = chunk // rb
        # Everything past a successful register sits inside the
        # try/cleanup below (mp/uploader as None sentinels until
        # created): store.multipart() or a user ReduceOp.open() raising
        # must still retire the grant and abort any created session, or
        # re-execution would deduct the budget pool a second time.
        mp = None
        uploader = None

        def submit_part(data: bytes) -> None:
            nonlocal next_part
            idx, next_part = next_part, next_part + 1
            t = time.perf_counter()  # blocks under upload backpressure
            uploader.submit(timed_part, timeline, tag, mp, idx, data)
            timeline.add("reduce.upload_wait", t, worker=tag)

        try:
            cursors = [
                RunCursor(store, shared.bucket, key, lo, hi, pw,
                          chunk_records)
                for key, lo, hi in slices
            ]
            mp = store.multipart(shared.bucket, op.output_key(r),
                                 metadata=op.output_metadata(r, n_total))
            # max_inflight >= fanout, or the backpressure semaphore would
            # silently cap concurrent part uploads below the fan-out
            # width.
            uploader = staging.AsyncWriter(
                max(plan.max_inflight_writes, plan.part_upload_fanout),
                max_workers=plan.part_upload_fanout)
            sink = op.open(r, n_total)
            # Optional sink protocol extension: a sink that runs its own
            # execution stage (shuffle/sort._DeviceMergeSink's async
            # device merge) gets the timeline and this partition's tag
            # so its off-thread work records spans like everything else.
            if hasattr(sink, "bind_exec"):
                sink.bind_exec(timeline=timeline, tag=tag)
            # A sink that only knows its output size at the end
            # (aggregation) reserves part 0 for the deferred header and
            # streams body parts from index 1 — the out-of-order
            # multipart contract (parts are assembled by index at
            # complete()) is what makes this legal.
            first_part = 1 if sink.deferred_part0 else 0
            next_part = first_part
            outbuf = bytearray(sink.begin())
            if sequential:
                # Sequential drain: one cursor at a time, run slices
                # forwarded to the sink in source order (deterministic —
                # the same bytes at any parallelism or worker count).
                for ci, c in enumerate(cursors):
                    while True:
                        if shared.control.cancel.is_set():
                            raise SiblingFailed()
                        if (self.gate_poll and self.commit_gate is not None
                                and not self.commit_gate(r)):
                            raise AttemptLost()
                        if registered:
                            grown = governor.grow(akey) // rb
                            if grown != chunk_records:
                                chunk_records = grown
                                c.set_chunk(grown)
                        if c.k64.size == 0 and c.has_more_remote:
                            t = time.perf_counter()
                            c.refill()
                            timeline.add("reduce.fetch", t, worker=tag)
                        shared.peak.update(akey, c.buffered_bytes)
                        t = time.perf_counter()
                        frag = c.take_upto(None)
                        done = ci == len(cursors) - 1 and c.exhausted
                        body = sink.consume([frag], final=done)
                        if body:
                            outbuf += body
                        timeline.add("reduce.merge", t, worker=tag)
                        while len(outbuf) >= part_bytes:
                            submit_part(bytes(outbuf[:part_bytes]))
                            del outbuf[:part_bytes]
                        if c.exhausted:
                            break
                cursors = []
            while cursors:
                if shared.control.cancel.is_set():
                    raise SiblingFailed()
                if (self.gate_poll and self.commit_gate is not None
                        and not self.commit_gate(r)):
                    raise AttemptLost()
                if registered:
                    # Adaptive governor: soak up budget freed by retired
                    # reducers — the per-run chunk can only grow.
                    grown = governor.grow(akey) // rb
                    if grown != chunk_records:
                        chunk_records = grown
                        for c in cursors:
                            c.set_chunk(grown)
                need = [c for c in cursors
                        if c.k64.size == 0 and c.has_more_remote]
                if need:
                    t = time.perf_counter()
                    if len(need) == 1:
                        need[0].refill()
                    else:  # concurrent ranged GETs: one RTT per cycle
                        # bind_context: the shared refill pool's threads
                        # must issue these GETs as THIS partition's.
                        list(refill_pool.map(bind_context(RunCursor.refill),
                                             need))
                    timeline.add("reduce.fetch", t, worker=tag)
                shared.peak.update(akey,
                                   sum(c.buffered_bytes for c in cursors))
                t = time.perf_counter()
                # Safe emit bound: the smallest last-buffered key among
                # runs that still have un-fetched records — nothing
                # later can sort below it. When no run has remote data
                # left, everything buffered is emittable (and this is
                # guaranteed to be the final cycle: any cursor with
                # remote data would survive the exhausted filter).
                remote_tails = [c.k64[-1] for c in cursors
                                if c.has_more_remote]
                bound = min(remote_tails) if remote_tails else None
                frags = [c.take_upto(bound) for c in cursors]
                cursors = [c for c in cursors if not c.exhausted]
                body = sink.consume(frags, final=bound is None)
                if body:
                    outbuf += body
                timeline.add("reduce.merge", t, worker=tag)
                while len(outbuf) >= part_bytes:
                    submit_part(bytes(outbuf[:part_bytes]))
                    del outbuf[:part_bytes]
            # finalize can block on real merge work (the device sink's
            # in-flight window) — record it under reduce.merge so the
            # span is the COMPLETE scheduler-visible merge cost.
            t = time.perf_counter()
            tail, part0 = sink.finalize()
            timeline.add("reduce.merge", t, worker=tag)
            if tail:
                outbuf += tail
                while len(outbuf) >= part_bytes:
                    submit_part(bytes(outbuf[:part_bytes]))
                    del outbuf[:part_bytes]
            # >= 1 part always: a partition with no body bytes still
            # uploads its header (inline for header-first sinks, as the
            # deferred part 0 below otherwise).
            if outbuf or (next_part == first_part and part0 is None):
                submit_part(bytes(outbuf))
            if part0 is not None:
                t = time.perf_counter()
                uploader.submit(timed_part, timeline, tag, mp, 0, part0)
                timeline.add("reduce.upload_wait", t, worker=tag)
        except BaseException:
            # Setup, merge, or upload died mid-session: let in-flight
            # parts settle, then discard the session — never commit it.
            try:
                if uploader is not None:
                    uploader.drain()
            except BaseException:
                pass
            try:
                if mp is not None:
                    mp.abort()
            except BaseException:
                pass  # a dead worker's abort fails too; parts are orphaned
            finally:
                shared.peak.clear(akey)
                if registered:
                    governor.retire(akey, completed=False)
                if uploader is not None:
                    uploader.close()
            raise
        # Success: hand drain + complete to the finisher queue so this
        # scheduler slot frees while the tail parts still upload —
        # finishers.submit blocks once max(max_inflight_writes, width)
        # sessions await completion (cross-partition upload backpressure).
        shared.peak.clear(akey)
        if registered:
            governor.retire(akey)
        confirm = None if on_done is None else (lambda: on_done(r))
        gate = (None if self.commit_gate is None
                else (lambda: self.commit_gate(r)))
        finishers.submit(finalize_session, timeline, tag, uploader, mp,
                         confirm, commit_gate=gate)


#: Sentinel yielded through the prefetch pipeline when a map load
#: abandoned mid-fetch (AttemptLost): the consume loop skips the task —
#: no processing, no spills, no confirmation — and moves on.
_LOST = object()


def run_map_tasks(store: StoreBackend, bucket: str, map_op: MapOp,
                  pop_next: Callable[[], int | None], *, plan,
                  timeline: PhaseTimeline, control: JobControl,
                  tag_prefix: str = "",
                  on_done: Callable[[int], None] | None = None,
                  commit_gate: Callable[[int], bool] | None = None) -> None:
    """The staged map loop, shared by the single-host path and every
    cluster worker: claim tasks from `pop_next`, keep `prefetch_depth`
    split loads in flight ahead of processing (retry-aware against
    transient store stalls), and spill through one bounded write-behind
    queue.

    With `on_done` set (cluster mode), each task's spills are drained
    before it is confirmed — a worker that dies with spills in flight
    leaves the task unconfirmed (and re-executed) rather than
    half-spilled. Without it (single-host), the spill queue drains once
    at loop exit, so spill waits never serialize the wave pipeline.

    Pipelined mode (plan.map_pipeline true AND the MapOp implements the
    staged `device_step`/`encode_step` split, see shuffle/api.MapOp):
    instead of calling the monolithic `process`, each task's device
    stage and encode stage run on two single-thread stage executors with
    a two-deep in-flight window, so wave N's host decode (the prefetch
    threads, recorded as map.decode) overlaps wave N-1's device sort
    (map.device_sort) and wave N-2's spill encode (map.encode) — the
    paper's §2.4-§2.5 compute/transfer overlap applied WITHIN the map
    leg. Spill bytes, offsets, and confirmation order are identical to
    the monolithic path; only wall-clock concurrency (and the per-stage
    span names) change.

    `commit_gate(g)` (elastic speculation) is the loser-abort predicate:
    each task's load runs against a read-gated store view that raises
    AttemptLost once another attempt of that task has durably committed,
    so a straggling duplicate abandons its chunked fetch at the next
    chunk boundary instead of holding the phase open. The gate is also
    re-checked between load and process, skipping the compute/spill leg
    of an already-lost task outright.
    """
    popped: collections.deque[int] = collections.deque()
    pipelined = (bool(getattr(plan, "map_pipeline", False))
                 and hasattr(map_op, "device_step")
                 and hasattr(map_op, "encode_step"))

    def loads():
        # Pulled from inside the prefetch pipeline on the caller's
        # thread budget: each pull claims the next task (up to
        # prefetch_depth ahead of processing). A claimed-but-unconfirmed
        # task at death is simply re-executed by the driver's next round.
        # Each load is bound to ITS task's TraceContext at claim time:
        # task g+1's prefetched GETs must not be attributed to task g,
        # which is what the processing thread's ambient context says.
        while not control.cancel.is_set():
            g = pop_next()
            if g is None:
                return
            popped.append(g)
            ctx = _task_context("map", f"g{g}", tag_prefix)
            # AttemptLost must be absorbed INSIDE the thunk: escaping
            # the prefetch iterator would unwind the whole pipeline and
            # take the worker's other in-flight claims down with it.
            view = (store if commit_gate is None
                    else _AbandonGatedReads(store,
                                            lambda g=g: commit_gate(g)))
            if pipelined:
                def load_one(g=g, view=view):
                    t = time.perf_counter()
                    try:
                        data = map_op.load(view, bucket, g)
                    except AttemptLost:
                        return _LOST
                    timeline.add("map.decode", t, worker=f"{tag_prefix}g{g}")
                    return data
                yield bind_context(load_one, ctx)
            else:
                def load_one(g=g, view=view):
                    try:
                        return map_op.load(view, bucket, g)
                    except AttemptLost:
                        return _LOST
                yield bind_context(load_one, ctx)

    with staging.AsyncWriter(plan.max_inflight_writes) as spiller:
        task_iter = iter(staging.prefetch(
            loads(), depth=plan.prefetch_depth,
            retries=plan.io_retries, retry_on=(RetryableError,)))
        if pipelined:
            _run_map_pipelined(store, bucket, map_op, task_iter, popped,
                               timeline=timeline, tag_prefix=tag_prefix,
                               spiller=spiller, on_done=on_done,
                               commit_gate=commit_gate)
            return
        while True:
            t_wait = time.perf_counter()
            try:
                data = next(task_iter)
            except StopIteration:
                return
            g = popped.popleft()
            if data is _LOST or (commit_gate is not None
                                 and not commit_gate(g)):
                continue  # another attempt already committed this task
            tag = f"{tag_prefix}g{g}"
            timeline.add("map.wait", t_wait, worker=tag)
            # Processing runs under the task's TraceContext so spill puts
            # (captured by the spiller at submit) carry the attribution.
            with use_context(_task_context("map", f"g{g}", tag_prefix)):
                map_op.process(store, bucket, g, data, spiller=spiller,
                               timeline=timeline, tag=tag)
            if on_done is not None:
                spiller.drain()
                on_done(g)


def _run_map_pipelined(store, bucket, map_op, task_iter, popped, *,
                       timeline: PhaseTimeline, tag_prefix: str, spiller,
                       on_done: Callable[[int], None] | None,
                       commit_gate: Callable[[int], bool] | None = None
                       ) -> None:
    """The double-buffered stage executor behind run_map_tasks.

    Two single-thread pools — one per stage — keep stage order FIFO per
    stage while letting stages of different tasks overlap: the encode
    job for task N blocks on task N's device future, the single device
    thread runs task N+1's sort meanwhile, and the prefetch threads
    decode task N+2. The in-flight window is two tasks deep (claim task
    N only after task N-2's encode finished), bounding host memory at
    ~two waves of sorted output beyond what the monolithic loop holds.

    Failure semantics match the monolithic loop: the first stage
    exception (including a cluster WorkerFailure from a spill) re-raises
    here in task order, and `on_done` confirmation still happens only
    after THAT task's encode completed and its spills drained.
    """
    sort_pool = ThreadPoolExecutor(max_workers=1,
                                   thread_name_prefix="map-sort")
    enc_pool = ThreadPoolExecutor(max_workers=1,
                                  thread_name_prefix="map-encode")
    inflight: collections.deque = collections.deque()

    def finish_one() -> None:
        g, fut = inflight.popleft()
        fut.result()  # re-raises the task's first stage failure
        if on_done is not None:
            spiller.drain()
            on_done(g)

    try:
        while True:
            t_wait = time.perf_counter()
            try:
                data = next(task_iter)
            except StopIteration:
                break
            g = popped.popleft()
            if data is _LOST or (commit_gate is not None
                                 and not commit_gate(g)):
                continue  # another attempt already committed this task
            tag = f"{tag_prefix}g{g}"
            timeline.add("map.wait", t_wait, worker=tag)
            ctx = _task_context("map", f"g{g}", tag_prefix)
            sort_fut = sort_pool.submit(bind_context(
                lambda g=g, d=data, tag=tag: map_op.device_step(
                    g, d, timeline=timeline, tag=tag), ctx))
            enc_fut = enc_pool.submit(bind_context(
                lambda g=g, sf=sort_fut, tag=tag: map_op.encode_step(
                    store, bucket, g, sf.result(), spiller=spiller,
                    timeline=timeline, tag=tag), ctx))
            inflight.append((g, enc_fut))
            while len(inflight) >= 2:
                finish_one()
        while inflight:
            finish_one()
    finally:
        sort_pool.shutdown(wait=True)
        enc_pool.shutdown(wait=True)


__all__ = [
    "AdaptiveBudgetGovernor",
    "AttemptLost",
    "JobControl",
    "PeakTracker",
    "PhaseTimeline",
    "ReduceScheduler",
    "ReduceShared",
    "RunCursor",
    "SiblingFailed",
    "Span",
    "finalize_session",
    "merge_fragments",
    "reduce_chunking",
    "run_map_tasks",
    "timed_part",
    "timed_put",
]
