"""Architecture configuration — one dataclass covers all 10 assigned archs.

Families:
  dense  : decoder-only transformer, GQA attention (granite, mistral-nemo,
           tinyllama; llava's backbone)
  mla    : dense with Multi-head Latent Attention (minicpm3)
  moe    : dense attention + mixture-of-experts FFN (qwen2-moe, moonshot)
  ssm    : xLSTM recurrent blocks, no FFN (xlstm-125m)
  hybrid : parallel attention + SSM heads per block (hymba)
  encdec : encoder-decoder with stubbed conv frontend (whisper)
  vlm    : dense backbone + stubbed patch-embedding frontend (llava-next)
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | mla | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    shared_d_ff: int = 0  # combined shared-expert width (0 = none)
    dispatch_impl: str = "sort"  # sort | onehot | dense (single-device)
    moe_capacity_factor: float = 1.25

    # --- MLA (minicpm3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    # pad the SSM head count up to this value (0 = off) so heads shard
    # evenly over the model axis; padded heads have zero input gate and
    # never contribute (see EXPERIMENTS.md §Perf, hymba cell).
    ssm_pad_heads: int = 0
    window: int = 0  # sliding-window size (0 = full attention)
    global_layers: Sequence[int] = ()  # layers with full attention (hybrid)
    chunk: int = 256  # chunkwise-recurrence length (mLSTM / SSD)
    meta_tokens: int = 0  # hymba learnable prefix

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0

    # --- frontends (stubs) ---
    vlm_prefix: int = 0  # patch-embedding positions reserved at seq front

    # --- numerics / parallelism policy ---
    dtype: str = "bfloat16"
    param_sharding: str = "tp"  # tp | fsdp
    # "seq": activations stay sequence-sharded into attention (baseline —
    #   GSPMD partitions the chunked-attention loop poorly; kept selectable
    #   for the before/after in EXPERIMENTS.md §Perf).
    # "heads": explicit head-parallel constraints on q/k/v around attention
    #   (Megatron-style: model axis shards heads, seq gathered locally).
    attn_sharding: str = "seq"
    # enumerate only lower-triangular (q-chunk, kv-chunk) attention pairs —
    # halves attention flops/tile-traffic vs the rectangular grid.
    causal_skip: bool = False
    remat: bool = True
    attn_chunk: int = 512  # flash-attention KV block
    train_microbatches: int = 1  # gradient-accumulation steps per train step

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 256 so the embedding/logits shard evenly over
        any TP degree up to 256 (standard practice; pad ids are never
        targeted by the loss)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def n_experts_padded(self) -> int:
        """Routed expert count rounded up to 16 so the expert stacks shard
        evenly over any EP degree up to 16 (or 64 with 16 | E). Pad experts
        exist as parameters but the router never selects them (qwen2-moe:
        60 -> 64)."""
        if self.n_experts == 0:
            return 0
        return ((self.n_experts + 15) // 16) * 16

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def is_moe(self) -> bool:
        return self.family == "moe"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic state: SSM or hybrid (windowed + SSM) archs."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs autoregress (whisper is enc-dec)

    def params_dense(self) -> int:
        """Approximate parameter count (embedding + blocks), for rooflines."""
        d, l = self.d_model, self.n_layers
        emb = self.vocab * d
        if self.family in ("dense", "vlm", "moe"):
            attn = d * self.n_heads * self.head_dim * 2 + d * self.n_kv_heads * self.head_dim * 2
        elif self.family == "mla":
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        elif self.family == "ssm":
            attn = 4 * d * d  # qkv + gates + out of the mLSTM block
        elif self.family == "hybrid":
            attn = d * self.n_heads * self.head_dim * 2 + d * self.n_kv_heads * self.head_dim * 2
            attn += 2 * d * d // 2  # ssm branch in/out
        elif self.family == "encdec":
            attn = 4 * d * d * 2  # self + cross (decoder); enc counted via layers
        else:
            attn = 4 * d * d
        if self.is_moe:
            ffn = self.n_experts * 3 * d * self.d_ff_expert + 3 * d * self.shared_d_ff
            ffn += d * self.n_experts  # router
        elif self.d_ff > 0:
            ffn = 3 * d * self.d_ff
        else:
            ffn = 0
        return emb + l * (attn + ffn)

    def params_active(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.params_dense()
        d, l = self.d_model, self.n_layers
        dense = self.params_dense()
        routed_all = l * self.n_experts * 3 * d * self.d_ff_expert
        routed_active = l * self.top_k * 3 * d * self.d_ff_expert
        return dense - routed_all + routed_active

    def reduced(self, **over) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab=256,
            attn_chunk=32,
            chunk=16,
            param_sharding="tp",
        )
        if self.is_moe:
            # capacity high enough that smoke tests never drop tokens —
            # capacity-dropping depends on how many tokens compete, which
            # legitimately differs between forward (B*S) and decode (B),
            # and would break decode-parity checks.
            small.update(n_experts=8, top_k=2, d_ff_expert=32, shared_d_ff=64,
                         dispatch_impl="dense", moe_capacity_factor=8.0)
        if self.family == "mla":
            small.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                         qk_rope_dim=8, v_head_dim=16)
        if self.family in ("ssm", "hybrid"):
            small.update(ssm_state=8)
        if self.family == "hybrid":
            small.update(window=32, global_layers=(0,), meta_tokens=8)
        if self.family == "encdec":
            small.update(enc_layers=2)
        if self.family == "vlm":
            small.update(vlm_prefix=16)
        small.update(over)
        return dataclasses.replace(self, **small)
