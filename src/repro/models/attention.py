"""Attention: flash-style chunked softmax attention + KV-cache decode.

Training/prefill uses an online-softmax two-level chunking (scan over query
chunks, scan over key chunks) so the (Sq, Sk) score matrix never
materializes — per-step footprint is O(cq * ck) per head. This is the
standard TPU-friendly flash formulation: every inner step is two MXU
matmuls over VMEM-resident chunks.

The baseline computes the full rectangular chunk grid with causal masking
(the masked upper triangle is ~2x FLOP waste, visible in the roofline's
MODEL_FLOPS/HLO ratio); `causal_skip=True` enumerates only the
lower-triangular chunk pairs — the beyond-paper optimization measured in
EXPERIMENTS.md §Perf.

GQA is handled by grouping query heads per KV head — KV chunks are never
materialized at full query-head width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _attn_chunk_step(acc, m, l, q, k, v, q_pos, k_pos, *, causal, window, scale,
                     k_limit=None, n_sink=0):
    """One (q-chunk, k-chunk) online-softmax update.

    q (B, cq, KV, G, dh); k/v (B, ck, KV, dh); q_pos (cq,); k_pos (ck,).
    acc (B, KV, G, cq, dh); m, l (B, KV, G, cq).

    Masking is a single additive (cq, ck) bias folded into the scaled
    scores — one broadcast-add over the (B, KV, G, cq, ck) tile instead of
    two boolean selects (§Perf iteration: the selects were two extra full
    passes over the largest tensor in the training step). Masked lanes get
    NEG_INF, so exp(s - m_new) underflows to 0 exactly and no post-exp
    select is needed; the fully-masked-row guard on alpha covers the rest.
    """
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    bias = jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    if causal:
        bias = jnp.where(k_pos[None, :] <= q_pos[:, None], bias, NEG_INF)
    # `window` may be a static int (0 = full attention) or a traced scalar
    # (scan-over-heterogeneous-layers; <= 0 or huge means full attention).
    if not (isinstance(window, int) and window == 0):
        win = jnp.asarray(window, jnp.int32)
        win = jnp.where(win > 0, win, jnp.int32(2**30))
        in_win = k_pos[None, :] > q_pos[:, None] - win
        if n_sink:  # always-attendable leading positions (hymba meta tokens)
            in_win |= (k_pos < n_sink)[None, :]
        bias = jnp.where(in_win, bias, NEG_INF)
    if k_limit is not None:  # ragged-tail key padding
        bias = jnp.where((k_pos < k_limit)[None, :], bias, NEG_INF)
    s = s + bias[None, None, None]

    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    # Guard fully-masked rows (m_new == NEG_INF): exp(NEG_INF - NEG_INF) = 1
    # would pollute l; rescale with 0 there.
    alpha = jnp.where(m > NEG_INF / 2, jnp.exp(m - m_new), 0.0)
    # masked lanes: s = NEG_INF and m_new >= 0ish only if some lane is live;
    # exp(NEG_INF - m_new) == 0, so p needs no select. Fully-masked rows
    # (m_new == NEG_INF) would give exp(0) = 1 — zero those explicitly via
    # the same guard used for alpha.
    row_live = (m_new > NEG_INF / 2)[..., None]
    p = jnp.exp(s - jnp.where(row_live, m_new[..., None], 0.0))
    p = p * row_live  # single cheap multiply, no (cq,ck) bool tile
    l_new = l * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v).astype(jnp.float32)
    acc_new = acc * alpha[..., None] + pv
    return acc_new, m_new, l_new


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 512,
    q_chunk: int | None = None,
    q_offset=0,
    k_offset=0,
    causal_skip: bool = False,
    n_sink: int = 0,
    _k_limit=None,
):
    """q (B, Sq, H, dh); k, v (B, Sk, KV, dh); H % KV == 0.

    `q_chunk=None` uses `chunk` for both grids; `q_chunk=0` disables the
    global q-chunk loop (cq = Sq — the online softmax still streams over
    kv chunks). Under GSPMD, q-chunking reshapes the sequence dim into
    (nq, cq), which destroys a sequence sharding whenever nq doesn't
    divide the mesh axis — disabling it keeps q shardable on seq
    (the `attn_sharding="qfull"` mode; see EXPERIMENTS.md §Perf).

    Returns (B, Sq, H, dh) in q.dtype.
    """
    b, sq, h, dh = q.shape
    _, sk, kv, _ = k.shape
    dv = v.shape[-1]  # may differ from dh (MLA: qk 96, v 64)
    g = h // kv
    scale = dh**-0.5
    cq = sq if q_chunk == 0 else min(q_chunk or chunk, sq)
    ck = min(chunk, sk)
    # Pad ragged tails to a whole chunk; key pads get an out-of-range
    # position (masked by the causal test), query pad rows are sliced off.
    sq_pad = -sq % cq
    sk_pad = -sk % ck
    if sq_pad or sk_pad:
        qp = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        out = flash_attention(
            qp, kp, vp, causal=causal, window=window, chunk=chunk,
            q_chunk=q_chunk, q_offset=q_offset, k_offset=k_offset,
            causal_skip=causal_skip, n_sink=n_sink, _k_limit=k_offset + sk,
        )
        return out[:, :sq]
    nq, nk = sq // cq, sk // ck

    qg = q.reshape(b, nq, cq, kv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(b, nk, ck, kv, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, ck, kv, dv).transpose(1, 0, 2, 3, 4)
    q_positions = q_offset + jnp.arange(sq, dtype=jnp.int32)
    k_positions = k_offset + jnp.arange(sk, dtype=jnp.int32)

    if (causal_skip and causal and nq == nk
            and isinstance(window, int) and window == 0):
        return _flash_lower_triangular(
            qg, ks, vs, q_positions, k_positions, b, cq, ck, kv, g, dv, scale
        ).reshape(b, sq, h, dv).astype(q.dtype)

    def per_q_chunk(args):
        qc, qp = args  # (B, cq, KV, G, dh), (cq,)

        # Rematerialize each (q-chunk, kv-chunk) tile in the backward pass —
        # the flash-attention property. Without this the scan saves every
        # (cq, ck) probability tile, i.e. the full S^2 score matrix.
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, kc_vc_kp):
            acc, m, l = carry
            kc, vc, kp = kc_vc_kp
            acc, m, l = _attn_chunk_step(
                acc, m, l, qc, kc, vc, qp, kp,
                causal=causal, window=window, scale=scale, k_limit=_k_limit,
                n_sink=n_sink,
            )
            return (acc, m, l), None

        acc0 = jnp.zeros((b, kv, g, cq, dv), jnp.float32)
        m0 = jnp.full((b, kv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, cq), jnp.float32)
        kps = k_positions.reshape(nk, ck)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (ks, vs, kps))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, cq, dv)
        return out.transpose(0, 3, 1, 2, 4)  # (B, cq, KV, G, dv)

    qps = q_positions.reshape(nq, cq)
    if nq == 1:
        # no q-chunk loop: keeps the q sequence dim intact (shardable)
        out = per_q_chunk((qg[0], qps[0])).reshape(b, sq, h, dv)
        return out.astype(q.dtype)
    outs = jax.lax.map(per_q_chunk, (qg, qps))  # (nq, B, cq, KV, G, dv)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def _flash_lower_triangular(qg, ks, vs, q_positions, k_positions,
                            b, cq, ck, kv, g, dv, scale):
    """Causal-skip: visit only chunk pairs (qi, ki <= qi).

    Enumerates the nq(nq+1)/2 lower-triangular pairs in ki-major order per
    qi, scanning with per-q-chunk accumulators gathered/scattered by qi.
    Exactly halves attention FLOPs vs the rectangular grid (minus diagonal
    masking), with identical results.
    """
    nq = qg.shape[0]
    pairs = [(qi, ki) for qi in range(nq) for ki in range(qi + 1)]
    pair_q = jnp.array([p[0] for p in pairs], jnp.int32)
    pair_k = jnp.array([p[1] for p in pairs], jnp.int32)

    acc0 = jnp.zeros((nq, b, kv, g, cq, dv), jnp.float32)
    m0 = jnp.full((nq, b, kv, g, cq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, kv, g, cq), jnp.float32)
    qps = q_positions.reshape(nq, cq)
    kps = k_positions.reshape(-1, ck)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def step(carry, pair):
        acc, m, l = carry
        qi, ki = pair
        a, mm, ll = _attn_chunk_step(
            acc[qi], m[qi], l[qi],
            qg[qi], ks[ki], vs[ki], qps[qi], kps[ki],
            causal=True, window=0, scale=scale,
        )
        return (acc.at[qi].set(a), m.at[qi].set(mm), l.at[qi].set(ll)), None

    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (pair_q, pair_k))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (nq, B, KV, G, cq, dv)
    return out.transpose(1, 0, 4, 2, 3, 5)  # (B, nq, cq, KV, G, dv)


def decode_attention(q, cache_k, cache_v, *, cache_len, window: int = 0):
    """Single-step attention against a KV cache.

    q (B, 1, H, dh); cache_k/v (B, L, KV, dh); cache_len scalar int32 =
    number of valid entries. For ring-buffer (windowed) caches, all L slots
    are valid once cache_len >= L; masking handles warm-up.
    Returns (B, 1, H, dh).
    """
    b, _, h, dh = q.shape
    _, lcache, kv, _ = cache_k.shape
    dv = cache_v.shape[-1]
    g = h // kv
    scale = dh**-0.5
    qg = q.reshape(b, kv, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k).astype(jnp.float32) * scale
    idx = jnp.arange(lcache, dtype=jnp.int32)
    valid = idx < cache_len
    if window:
        valid = idx < jnp.minimum(cache_len, window)  # ring: all slots once full
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(cache_v.dtype), cache_v)
    return out.reshape(b, 1, h, dv).astype(q.dtype)
