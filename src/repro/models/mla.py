"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Queries and KV are low-rank compressed; the KV cache stores only the
compressed latent c_kv (kv_lora_rank) plus a shared RoPE key (qk_rope_dim)
per position — ~8x smaller than a GQA cache at equal quality.

Train/prefill uses the *expanded* form (decompress K/V per head and run
flash attention, MHA). Decode uses the *absorbed* form: W_uk is folded into
the query and W_uv into the output so attention runs directly against the
compressed cache — the latent never expands at decode time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.attention import NEG_INF, flash_attention
from repro.models.config import ArchConfig


def init_mla(key, cfg: ArchConfig):
    d = cfg.d_model
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "w_dq": layers.uniform_init(ks[0], (d, cfg.q_lora_rank)),
        "q_norm": layers.rmsnorm_init(cfg.q_lora_rank),
        "w_uq": layers.uniform_init(ks[1], (cfg.q_lora_rank, h * (dn + dr))),
        "w_dkv": layers.uniform_init(ks[2], (d, cfg.kv_lora_rank + dr)),
        "kv_norm": layers.rmsnorm_init(cfg.kv_lora_rank),
        "w_uk": layers.uniform_init(ks[3], (cfg.kv_lora_rank, h * dn)),
        "w_uv": layers.uniform_init(ks[4], (cfg.kv_lora_rank, h * dv)),
        "wo": layers.uniform_init(ks[5], (h * dv, d)),
    }


def _latents(p, cfg: ArchConfig, x, positions):
    """Shared q/kv compression. x (B,S,d) -> (q (B,S,H,dn+dr), c_kv, k_rope)."""
    dt = x.dtype
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim

    cq = layers.rmsnorm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(dt)))
    q = jnp.einsum("bsr,rh->bsh", cq, p["w_uq"].astype(dt)).reshape(b, s, h, dn + dr)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(dt))
    c_kv = layers.rmsnorm(p["kv_norm"], ckv_full[..., : cfg.kv_lora_rank])
    k_rope = ckv_full[..., cfg.kv_lora_rank :]  # (B, S, dr), shared over heads

    cos, sin = layers.rope_frequencies(dr, cfg.rope_theta, positions)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, cos, sin)
    k_rope = layers.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    return q, c_kv, k_rope


def mla_attention(p, cfg: ArchConfig, x, positions, *, causal_skip=False,
                  mesh=None, dp_axes=("data",)):
    """Expanded-form MLA for train/prefill. Returns (out, (c_kv, k_rope))."""
    dt = x.dtype
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    q, c_kv, k_rope = _latents(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rh->bsh", c_kv, p["w_uk"].astype(dt)).reshape(b, s, h, dn)
    v = jnp.einsum("bsr,rh->bsh", c_kv, p["w_uv"].astype(dt)).reshape(b, s, h, dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], axis=-1
    )
    # minicpm3's 40 heads don't divide a 16-way model axis; without an
    # explicit layout the w_uq TP sharding propagates head_dim sharding
    # into the score contraction (per-tile psums — see EXPERIMENTS.md
    # §Perf, hymba cell for the identical pathology).
    if cfg.attn_sharding == "qfull":
        q = layers.constrain_seq(q, mesh, dp_axes)
        k = layers.constrain_seq(k, mesh, dp_axes)
        v = layers.constrain_seq(v, mesh, dp_axes)
    elif cfg.attn_sharding == "heads":
        q = layers.constrain_heads(q, mesh, dp_axes)
        k = layers.constrain_heads(k, mesh, dp_axes)
        v = layers.constrain_heads(v, mesh, dp_axes)
    out = flash_attention(
        q, k, v, causal=True, chunk=cfg.attn_chunk,
        q_chunk=0 if cfg.attn_sharding == "qfull" else None,
        causal_skip=causal_skip,
    )  # (B,S,H,dv)
    if cfg.attn_sharding == "qfull":
        out = layers.constrain_seq(out, mesh, dp_axes)
    elif cfg.attn_sharding == "heads":
        out = layers.constrain_heads(out, mesh, dp_axes)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, h * dv), p["wo"].astype(dt))
    return out, (c_kv, k_rope)


def mla_decode(p, cfg: ArchConfig, x, cache_c, cache_kr, pos):
    """Absorbed-form single-step decode against the compressed cache.

    x (B,1,d); cache_c (B,L,kv_lora); cache_kr (B,L,dr); pos scalar — the
    index of the new token (cache holds `pos` valid entries; the new
    latent is written at `pos`).
    Returns (out (B,1,d), cache_c, cache_kr).
    """
    dt = x.dtype
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank

    q, c_kv, k_rope = _latents(p, cfg, x, pos[None] if pos.ndim == 0 else pos)
    cache_c = jax.lax.dynamic_update_slice(cache_c, c_kv, (0, pos, 0))
    cache_kr = jax.lax.dynamic_update_slice(cache_kr, k_rope, (0, pos, 0))

    q_nope = q[..., :dn].reshape(b, h, dn)
    q_rope = q[..., dn:].reshape(b, h, dr)
    # absorb W_uk: q_eff (B,H,r) scores directly against the latent cache.
    w_uk = p["w_uk"].astype(dt).reshape(r, h, dn)
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)
    s = jnp.einsum("bhr,blr->bhl", q_eff, cache_c)
    s = s + jnp.einsum("bhr,blr->bhl", q_rope, cache_kr)
    s = s.astype(jnp.float32) * (dn + dr) ** -0.5
    idx = jnp.arange(cache_c.shape[1], dtype=jnp.int32)
    s = jnp.where(idx[None, None] <= pos, s, NEG_INF)
    pweights = jax.nn.softmax(s, axis=-1).astype(dt)
    ctx = jnp.einsum("bhl,blr->bhr", pweights, cache_c)  # attended latent
    # absorb W_uv on the way out.
    w_uv = p["w_uv"].astype(dt).reshape(r, h, dv)
    attn = jnp.einsum("bhr,rhv->bhv", ctx, w_uv).reshape(b, 1, h * dv)
    out = jnp.einsum("bsh,hd->bsd", attn, p["wo"].astype(dt))
    return out, cache_c, cache_kr
