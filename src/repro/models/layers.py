"""Common neural layers: pure functions over explicit param pytrees.

Parameters are plain nested dicts of jnp arrays; init functions take a PRNG
key and return the pytree. Every layer is written to be scanned over a
stacked (L, ...) parameter axis and to lower compactly for the 512-device
dry-run.

Numerics: parameters are stored in float32 ("master" dtype); forward casts
to the config compute dtype (bf16) at use. RMSNorm and softmax accumulate
in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def constrain_acts(x, mesh, dp_axes, *, seq_axis: int = 1):
    """Sequence-parallel activation constraint at block boundaries.

    x (B, S, d): batch over the data axes, sequence over 'model'. The saved
    scan carry per layer then occupies 1/(dp*tp) of the global activation —
    GSPMD all-gathers the sequence dim where a block genuinely needs full
    context (attention) and reduce-scatters after (Megatron-SP, derived
    automatically from the constraint).
    """
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    entries = [None] * x.ndim
    dp = tuple(dp_axes)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    if x.shape[0] % ndp == 0:
        entries[0] = dp
    if "model" in mesh.axis_names and x.shape[seq_axis] % mesh.shape["model"] == 0:
        entries[seq_axis] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))


def constrain_heads(x, mesh, dp_axes):
    """Head-parallel attention constraint on (B, S, H, dh).

    Batch over the data axes; heads over 'model' when divisible (q heads),
    otherwise left to GSPMD (GQA kv heads with KV < tp propagate a partial
    sharding from q's KV x G factorization). Sequence replicated — GSPMD
    inserts the all-gather from the sequence-parallel block boundary and a
    reduce-scatter after the output projection (Megatron-SP attention).
    """
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    entries: list = [None] * x.ndim
    dp = tuple(dp_axes)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    if x.shape[0] % ndp == 0:
        entries[0] = dp
    if "model" in mesh.axis_names and x.shape[2] % mesh.shape["model"] == 0:
        entries[2] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))


def constrain_seq(x, mesh, dp_axes, *, seq_axis: int = 1):
    """Sequence-parallel constraint on (B, S, H, dh) attention inputs.

    Batch over the data axes, sequence over 'model', heads/dh replicated —
    the `attn_sharding="qfull"` layout for archs whose head count doesn't
    divide the TP degree (hymba: 25 heads over 16). Without this, the TP
    sharding of wq propagates *head_dim* sharding into the score einsum's
    contracted dim: one all-reduce per attention tile (7 TiB/step on the
    hymba prefill_32k baseline).
    """
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    entries: list = [None] * x.ndim
    dp = tuple(dp_axes)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    if x.shape[0] % ndp == 0:
        entries[0] = dp
    if "model" in mesh.axis_names and \
            x.shape[seq_axis] % mesh.shape["model"] == 0:
        entries[seq_axis] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))


def constrain_tree(tree, specs, mesh):
    """Constrain a pytree to PartitionSpecs. Used on the per-layer param
    slice inside scan bodies so the backward scan's gradient accumulators
    inherit the param sharding instead of materializing replicated."""
    if mesh is None or specs is None:
        return tree
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.tree.map(
        lambda t, s: jax.lax.with_sharding_constraint(t, NamedSharding(mesh, s)),
        tree, specs, is_leaf=lambda x: isinstance(x, P),
    )


def uniform_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else fan_in**-0.5
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def rmsnorm_init(dim):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float, positions: jax.Array):
    """positions (...,) int32 -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array):
    """x (..., seq, heads, head_dim); cos/sin (..., seq, head_dim//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


def embedding_init(key, vocab: int, dim: int):
    return {"table": jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02}


def embed(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def unembed(params, x):
    """Tied-transpose readout -> (..., vocab) in float32."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), params["table"].astype(jnp.float32)
    )


def swiglu_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": uniform_init(k1, (d_model, d_ff)),
        "w_up": uniform_init(k2, (d_model, d_ff)),
        "w_down": uniform_init(k3, (d_ff, d_model)),
    }


def swiglu(params, x):
    dt = x.dtype
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dt))
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(dt))


def gqa_proj_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": uniform_init(kq, (d_model, n_heads * head_dim)),
        "wk": uniform_init(kk, (d_model, n_kv_heads * head_dim)),
        "wv": uniform_init(kv, (d_model, n_kv_heads * head_dim)),
        "wo": uniform_init(ko, (n_heads * head_dim, d_model)),
    }


def qkv_project(params, x, n_heads, n_kv_heads, head_dim):
    dt = x.dtype
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(dt))
    return (
        q.reshape(b, s, n_heads, head_dim),
        k.reshape(b, s, n_kv_heads, head_dim),
        v.reshape(b, s, n_kv_heads, head_dim),
    )


def out_project(params, attn_out):
    dt = attn_out.dtype
    b, s, h, dh = attn_out.shape
    return jnp.einsum(
        "bsh,hd->bsd", attn_out.reshape(b, s, h * dh), params["wo"].astype(dt)
    )
