"""Chunked gated linear attention — the shared recurrence engine.

Both xLSTM's mLSTM (matrix memory + normalizer) and Mamba2-style SSD
(hymba's SSM heads) are instances of one recurrence over per-head state
S (dk, dv):

    S_t = exp(a_t) * S_{t-1} + exp(b_t) * k_t v_t^T      (a_t, b_t <= 0)
    y_t = q_t @ S_t            [ / max(|q_t . n_t|, 1) with normalizer n ]

Training/prefill uses the chunkwise-parallel form (scan over chunks of
length `chunk`, intra-chunk work is two MXU matmuls — the TPU-native
formulation); decode is the O(1)-state single step. All decay/input gates
live in log space and are bounded <= 0 (log-sigmoid), so every exponent in
the chunked form is <= 0 — no overflow without a running-max stabilizer.

Shapes: q, k (B, H, T, dk); v (B, H, T, dv); a, b (B, H, T).
State: S (B, H, dk, dv); n (B, H, dk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def gla_chunked(q, k, v, a, b, *, chunk: int = 256, normalize: bool = False,
                initial_state=None):
    """Returns (y (B, H, T, dv), (S, n) final state)."""
    bb, h, t, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, t)
    pad = -t % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)))  # pad decay 0 = keep state
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
    nc = (t + pad) // c

    def to_chunks(x, feat):
        if feat:
            return x.reshape(bb, h, nc, c, x.shape[-1]).transpose(2, 0, 1, 3, 4)
        return x.reshape(bb, h, nc, c).transpose(2, 0, 1, 3)

    qs, ks, vs = to_chunks(q, True), to_chunks(k, True), to_chunks(v, True)
    as_, bs = to_chunks(a, False), to_chunks(b, False)

    s0 = (
        initial_state[0]
        if initial_state is not None
        else jnp.zeros((bb, h, dk, dv), jnp.float32)
    )
    n0 = (
        initial_state[1]
        if initial_state is not None
        else jnp.zeros((bb, h, dk), jnp.float32)
    )
    tril = jnp.tril(jnp.ones((c, c), bool))

    # Rematerialize intra-chunk decay/score tiles in the backward pass
    # (flash-style); otherwise the scan saves every (c, c) D-matrix.
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_step(carry, xs):
        s, n = carry
        qc, kc, vc, ac, bc = xs
        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        f = jnp.cumsum(ac, axis=-1)  # (B,H,c) inclusive log-decay
        # intra-chunk: D[t,s] = exp(F_t - F_s + b_s), s <= t (exponent <= 0)
        logd = f[..., :, None] - f[..., None, :] + bc[..., None, :]
        d = jnp.where(tril, jnp.exp(logd), 0.0)
        qk = jnp.einsum("bhtd,bhsd->bhts", qf, kf)
        y = jnp.einsum("bhts,bhsv->bhtv", qk * d, vf)
        # inter-chunk: carried state
        ef = jnp.exp(f)
        y = y + ef[..., None] * jnp.einsum("bhtd,bhdv->bhtv", qf, s)
        if normalize:
            den = ef * jnp.einsum("bhtd,bhd->bht", qf, n) + jnp.sum(qk * d, -1)
            y = y / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # state update: w_s = exp(F_end - F_s + b_s)
        w = jnp.exp(f[..., -1:] - f + bc)
        s_new = jnp.exp(f[..., -1])[..., None, None] * s + jnp.einsum(
            "bhs,bhsd,bhsv->bhdv", w, kf, vf
        )
        n_new = jnp.exp(f[..., -1])[..., None] * n + jnp.einsum("bhs,bhsd->bhd", w, kf)
        return (s_new, n_new), y

    (s_f, n_f), ys = jax.lax.scan(chunk_step, (s0, n0), (qs, ks, vs, as_, bs))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(bb, h, nc * c, dv)[:, :, :t]
    return y.astype(v.dtype), (s_f, n_f)


def gla_step(q, k, v, a, b, state, *, normalize: bool = False):
    """Single decode step. q/k (B,H,dk); v (B,H,dv); a/b (B,H) log gates.

    Returns (y (B,H,dv), (S, n) updated state).
    """
    s, n = state
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    ea = jnp.exp(a)[..., None, None]
    eb = jnp.exp(b)[..., None, None]
    s_new = ea * s + eb * kf[..., :, None] * vf[..., None, :]
    n_new = ea[..., 0] * n + eb[..., 0] * kf
    y = jnp.einsum("bhd,bhdv->bhv", qf, s_new)
    if normalize:
        den = jnp.einsum("bhd,bhd->bh", qf, n_new)
        y = y / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return y.astype(v.dtype), (s_new, n_new)


def causal_conv1d(x, kernel, *, state=None):
    """Depthwise causal conv. x (B, T, D); kernel (K, D).

    state (B, K-1, D) holds the trailing inputs from the previous segment.
    Returns (y (B, T, D), new_state (B, K-1, D)).
    """
    kk = kernel.shape[0]
    bsz = x.shape[0]
    if state is None:
        state = jnp.zeros((bsz, kk - 1, x.shape[-1]), x.dtype)
    xx = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, T+K-1, D)
    y = sum(
        xx[:, i : i + x.shape[1]] * kernel[i].astype(x.dtype) for i in range(kk)
    )
    new_state = xx[:, -(kk - 1) :] if kk > 1 else state
    return y, new_state
