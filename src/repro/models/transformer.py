"""Decoder-only transformer: dense (GQA), MLA, MoE, and VLM-backbone paths.

One scanned block implementation covers granite-3-8b, mistral-nemo-12b,
tinyllama-1.1b, minicpm3-4b (MLA), qwen2-moe-a2.7b, moonshot-v1-16b-a3b
(MoE) and llava-next-34b (dense backbone behind a patch-embedding stub).

Layer stack is `lax.scan` over stacked (L, ...) params — one traced block
regardless of depth, which keeps the 512-device dry-run HLO compact — with
`jax.checkpoint` (remat) around the block body for training.

Entry points:
  init_params / abstract_params
  forward(params, tokens[, prefix_embeds])          -> logits (train)
  prefill(params, tokens)                           -> (last-pos logits, cache)
  decode_step(params, cache, token, pos)            -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers, mla, moe
from repro.models.attention import decode_attention, flash_attention
from repro.models.config import ArchConfig


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ArchConfig):
    ka, kf, kn = jax.random.split(key, 3)
    p: dict[str, Any] = {
        "ln_attn": layers.rmsnorm_init(cfg.d_model),
        "ln_ffn": layers.rmsnorm_init(cfg.d_model),
    }
    if cfg.family == "mla":
        p["attn"] = mla.init_mla(ka, cfg)
    else:
        p["attn"] = layers.gqa_proj_init(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        )
    if cfg.is_moe:
        p["ffn"] = moe.init_moe_ffn(kf, cfg)
    elif cfg.d_ff:
        p["ffn"] = layers.swiglu_init(kf, cfg.d_model, cfg.d_ff)
    del kn
    return p


def init_params(key, cfg: ArchConfig):
    ke, kb, kn = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(block_keys)
    return {
        "embed": layers.embedding_init(ke, cfg.padded_vocab, cfg.d_model),
        "blocks": blocks,  # every leaf stacked (L, ...)
        "ln_f": layers.rmsnorm_init(cfg.d_model),
    }


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _attn_train(p, cfg: ArchConfig, x, positions, *, causal_skip=False,
                mesh=None, dp_axes=("data",)):
    if cfg.family == "mla":
        out, _ = mla.mla_attention(p, cfg, x, positions,
                                   causal_skip=causal_skip, mesh=mesh,
                                   dp_axes=dp_axes)
        return out
    q, k, v = layers.qkv_project(p, x, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    cos, sin = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta, positions)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    if cfg.attn_sharding == "heads":
        q = layers.constrain_heads(q, mesh, dp_axes)
        k = layers.constrain_heads(k, mesh, dp_axes)
        v = layers.constrain_heads(v, mesh, dp_axes)
    elif cfg.attn_sharding == "qfull":
        q = layers.constrain_seq(q, mesh, dp_axes)
        k = layers.constrain_seq(k, mesh, dp_axes)
        v = layers.constrain_seq(v, mesh, dp_axes)
    out = flash_attention(
        q, k, v, causal=True, window=cfg.window, chunk=cfg.attn_chunk,
        q_chunk=0 if cfg.attn_sharding == "qfull" else None,
        causal_skip=causal_skip,
    )
    if cfg.attn_sharding == "heads":
        out = layers.constrain_heads(out, mesh, dp_axes)
    elif cfg.attn_sharding == "qfull":
        out = layers.constrain_seq(out, mesh, dp_axes)
    return layers.out_project(p, out)


def _block_train(p, cfg: ArchConfig, x, positions, mesh, dp_axes, *, causal_skip=False):
    h = x + _attn_train(p["attn"], cfg, layers.rmsnorm(p["ln_attn"], x, cfg.norm_eps),
                        positions, causal_skip=causal_skip, mesh=mesh,
                        dp_axes=dp_axes)
    z = layers.rmsnorm(p["ln_ffn"], h, cfg.norm_eps)
    if cfg.is_moe:
        f = moe.moe_ffn(p["ffn"], cfg, z, mesh=mesh, dp_axes=dp_axes)
    elif cfg.d_ff:
        f = layers.swiglu(p["ffn"], z)
    else:
        f = 0.0
    return h + f


def forward(
    params,
    cfg: ArchConfig,
    tokens,
    *,
    prefix_embeds=None,
    mesh=None,
    dp_axes=("data",),
    causal_skip=False,
    block_specs=None,
):
    """tokens (B, S_text) int32; prefix_embeds (B, P, d) for VLM stubs.

    Returns logits (B, S, vocab) float32, where S = P + S_text.
    """
    dt = cfg.compute_dtype
    x = layers.embed(params["embed"], tokens, dt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dt), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(h, layer_params):
        h = layers.constrain_acts(h, mesh, dp_axes)
        layer_params = layers.constrain_tree(layer_params, block_specs, mesh)
        h = _block_train(layer_params, cfg, h, positions, mesh, dp_axes,
                         causal_skip=causal_skip)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return layers.unembed(params["embed"], x)


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Abstract/zero cache. MLA caches the latent; GQA caches full K/V."""
    if cfg.family == "mla":
        return {
            "c": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_lora_rank),
                           cfg.compute_dtype),
            "kr": jnp.zeros((cfg.n_layers, batch, max_len, cfg.qk_rope_dim),
                            cfg.compute_dtype),
        }
    return {
        "k": jnp.zeros(
            (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
            cfg.compute_dtype,
        ),
        "v": jnp.zeros(
            (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
            cfg.compute_dtype,
        ),
    }


def prefill(params, cfg: ArchConfig, tokens, *, max_len=None, prefix_embeds=None,
            mesh=None, dp_axes=("data",)):
    """Run the prompt, building the cache. Returns (logits_last, cache)."""
    dt = cfg.compute_dtype
    x = layers.embed(params["embed"], tokens, dt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dt), x], axis=1)
    b, s, _ = x.shape
    # s includes any VLM prefix; the cache must hold at least the prompt.
    max_len = max(max_len or s, s)
    positions = jnp.arange(s, dtype=jnp.int32)
    cos, sin = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta, positions)

    def body(h, layer_params):
        hn = layers.rmsnorm(layer_params["ln_attn"], h, cfg.norm_eps)
        if cfg.family == "mla":
            out, (c_kv, k_rope) = mla.mla_attention(layer_params["attn"], cfg,
                                                    hn, positions, mesh=mesh,
                                                    dp_axes=dp_axes)
            kv = {"c": _pad_len(c_kv, max_len), "kr": _pad_len(k_rope, max_len)}
        else:
            q, k, v = layers.qkv_project(
                layer_params["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            )
            q = layers.apply_rope(q, cos, sin)
            k = layers.apply_rope(k, cos, sin)
            if cfg.attn_sharding == "heads":
                q = layers.constrain_heads(q, mesh, dp_axes)
                k = layers.constrain_heads(k, mesh, dp_axes)
                v = layers.constrain_heads(v, mesh, dp_axes)
            out = flash_attention(
                q, k, v, causal=True, window=cfg.window,
                chunk=cfg.attn_chunk,
                q_chunk=0 if cfg.attn_sharding == "qfull" else None)
            if cfg.attn_sharding == "heads":
                out = layers.constrain_heads(out, mesh, dp_axes)
            out = layers.out_project(layer_params["attn"], out)
            kv = {"k": _pad_len(k, max_len), "v": _pad_len(v, max_len)}
        h = h + out
        z = layers.rmsnorm(layer_params["ln_ffn"], h, cfg.norm_eps)
        if cfg.is_moe:
            f = moe.moe_ffn(layer_params["ffn"], cfg, z, mesh=mesh, dp_axes=dp_axes)
        elif cfg.d_ff:
            f = layers.swiglu(layer_params["ffn"], z)
        else:
            f = 0.0
        return h + f, kv

    x, cache = jax.lax.scan(body, x, params["blocks"])
    x = layers.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = layers.unembed(params["embed"], x)
    return logits, cache


def _pad_len(arr, max_len):
    s = arr.shape[1]
    if s == max_len:
        return arr
    pad = [(0, 0)] * arr.ndim
    pad[1] = (0, max_len - s)
    return jnp.pad(arr, pad)


def decode_step(params, cfg: ArchConfig, cache, token, pos, *, mesh=None,
                dp_axes=("data",)):
    """One autoregressive step. token (B, 1) int32; pos scalar int32 — the
    number of valid cache entries (the new token's position).
    Returns (logits (B, 1, vocab), cache).
    """
    dt = cfg.compute_dtype
    x = layers.embed(params["embed"], token, dt)  # (B, 1, d)
    posv = jnp.asarray(pos, jnp.int32)

    def body(h, scanned):
        layer_params, layer_cache = scanned
        hn = layers.rmsnorm(layer_params["ln_attn"], h, cfg.norm_eps)
        if cfg.family == "mla":
            out, c_new, kr_new = mla.mla_decode(
                layer_params["attn"], cfg, hn, layer_cache["c"], layer_cache["kr"],
                posv,
            )
            new_cache = {"c": c_new, "kr": kr_new}
        else:
            q, k, v = layers.qkv_project(
                layer_params["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            )
            cos, sin = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta,
                                               posv[None])
            q = layers.apply_rope(q, cos, sin)
            k = layers.apply_rope(k, cos, sin)
            ck = jax.lax.dynamic_update_slice(
                layer_cache["k"], k, (0, posv, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                layer_cache["v"], v, (0, posv, 0, 0)
            )
            out = decode_attention(q, ck, cv, cache_len=posv + 1, window=cfg.window)
            out = layers.out_project(layer_params["attn"], out)
            new_cache = {"k": ck, "v": cv}
        h = h + out
        z = layers.rmsnorm(layer_params["ln_ffn"], h, cfg.norm_eps)
        if cfg.is_moe:
            # without the mesh the dispatch falls back to the dense
            # single-shard path, which all-gathers the full expert bank
            # per layer (15 GiB of temps on moonshot decode_32k).
            f = moe.moe_ffn(layer_params["ffn"], cfg, z, mesh=mesh,
                            dp_axes=dp_axes)
        elif cfg.d_ff:
            f = layers.swiglu(layer_params["ffn"], z)
        else:
            f = 0.0
        return h + f, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return layers.unembed(params["embed"], x), new_cache
