"""Mixture-of-experts FFN block (qwen2-moe, moonshot) with exoshuffle dispatch.

The routed-expert path is where the paper's technique lands in the LM stack
(DESIGN.md §4.2): token->expert routing is a shuffle with expert-id keys.
`dispatch_impl` selects:

  sort   — exoshuffle dispatch under shard_map (EP all_to_all over the
           `model` axis); the framework's first-class path.
  onehot — GShard dense-einsum baseline (pure GSPMD), for §Perf comparison.
  dense  — single-device fallback (sort pipeline minus the all_to_all);
           used by CPU smoke tests.

Shared experts (qwen2-moe has 4, fused here into one wide SwiGLU) run
dense alongside the routed path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import moe_dispatch as md
from repro.models import layers
from repro.models.config import ArchConfig


def init_moe_ffn(key, cfg: ArchConfig):
    d, e, fe = cfg.d_model, cfg.n_experts_padded, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.uniform_init(ks[0], (d, e)),
        "w_gate": layers.uniform_init(ks[1], (e, d, fe), scale=d**-0.5),
        "w_up": layers.uniform_init(ks[2], (e, d, fe), scale=d**-0.5),
        "w_down": layers.uniform_init(ks[3], (e, fe, d), scale=fe**-0.5),
    }
    if cfg.shared_d_ff:
        p["shared"] = layers.swiglu_init(ks[4], d, cfg.shared_d_ff)
    return p


def _expert_fn(params, xin):
    """Batched SwiGLU experts. params: dict with (E, ...) leaves; xin (E, C, d)."""
    dt = xin.dtype
    g = jnp.einsum("ecd,edf->ecf", xin, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xin, params["w_up"].astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"].astype(dt))


def moe_ffn(p, cfg: ArchConfig, x, *, mesh=None, dp_axes=("data",), ep_axis="model"):
    """x (B, S, d) -> (B, S, d)."""
    dt = x.dtype
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", tokens, p["router"].astype(dt))
    weights, ids = md.route_topk(logits, cfg.top_k)
    # router emits real-expert logits only; pad experts (n_experts_padded >
    # n_experts) are never routed to.

    expert_params = {k: p[k] for k in ("w_gate", "w_up", "w_down")}
    impl = cfg.dispatch_impl
    dcfg = md.MoeDispatchConfig(
        num_experts=cfg.n_experts_padded,
        top_k=cfg.top_k,
        capacity_factor=cfg.moe_capacity_factor,
        ep_axis=ep_axis,
    )

    if impl == "sort" and mesh is not None and s == 1:
        # decode: tokens (B) << mesh size — replicate tokens over the EP
        # axis, mask per-shard expert routing, psum the partial outputs.
        # (The all_to_all pipeline needs T divisible by dp*ep; see
        # moe_dispatch.ep_replicated_shard.)
        token_spec = P(tuple(dp_axes), None)
        w_spec = P(token_spec[0], None)
        ep_size = mesh.shape[ep_axis]

        def decode_fn(tok, w, i, ep):
            return md.ep_replicated_shard(
                tok, w, i, ep, cfg=dcfg, ep_size=ep_size,
                expert_fn=lambda prm, xin: _expert_fn(prm, xin),
            )

        routed = compat.shard_map(
            decode_fn,
            mesh=mesh,
            in_specs=(token_spec, w_spec, w_spec,
                      {k: P(ep_axis, None, None) for k in expert_params}),
            out_specs=token_spec,
            check_vma=False,
        )(tokens, weights, ids, expert_params)
    elif impl == "sort" and mesh is not None:
        token_spec = P(tuple(dp_axes) + (ep_axis,), None)
        w_spec = P(token_spec[0], None)
        ep_size = mesh.shape[ep_axis]

        def shard_fn(tok, w, i, ep):
            return md.sort_dispatch_shard(
                tok, w, i, ep, cfg=dcfg, ep_size=ep_size,
                expert_fn=lambda prm, xin: _expert_fn(prm, xin),
            )

        routed = compat.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(token_spec, w_spec, w_spec,
                      {k: P(ep_axis, None, None) for k in expert_params}),
            out_specs=token_spec,
            check_vma=False,
        )(tokens, weights, ids, expert_params)
    elif impl == "onehot":
        cap = md._round_up(
            tokens.shape[0] * cfg.top_k / cfg.n_experts * cfg.moe_capacity_factor, 8
        )
        routed = md.onehot_dispatch_combine(
            tokens, weights, ids,
            num_experts=cfg.n_experts_padded, capacity=int(cap),
            expert_fn=lambda xin: _expert_fn(expert_params, xin),
        )
    else:  # dense: single-shard sort pipeline (no collective)
        routed = md.sort_dispatch_shard(
            tokens, weights, ids, expert_params,
            cfg=dcfg, ep_size=1,
            expert_fn=lambda prm, xin: _expert_fn(prm, xin),
        )

    out = routed.reshape(b, s, d).astype(dt)
    if cfg.shared_d_ff:
        out = out + layers.swiglu(p["shared"], x)
    return out
