"""Unified model API: build(cfg) -> ModelApi with train/serve entry points
and ShapeDtypeStruct input specs for every assigned benchmark shape.

Shape cells (assignment):
  train_4k    : seq 4096,   global_batch 256  -> train_step lowering
  prefill_32k : seq 32768,  global_batch 32   -> prefill lowering
  decode_32k  : seq 32768,  global_batch 128  -> decode_step w/ 32k cache
  long_500k   : seq 524288, global_batch 1    -> decode_step (ssm/hybrid only)

Family conventions:
  vlm    : seq = vlm_prefix patch embeddings + text tokens (frontend stub
           supplies the patch embeddings).
  encdec : enc_len = seq//4 frame embeddings (conv frontend stub) +
           seq text tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import hymba, transformer, whisper, xlstm
from repro.models.config import ArchConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


@dataclasses.dataclass
class ModelApi:
    cfg: ArchConfig
    init: Callable[..., Any]
    forward: Callable[..., Any]  # (params, batch) -> logits
    loss: Callable[..., Any]  # (params, batch) -> scalar
    prefill: Callable[..., Any]  # (params, batch) -> (logits, cache)
    decode_step: Callable[..., Any]  # (params, cache, token, pos) -> (logits, cache)
    init_cache: Callable[..., Any]  # (batch, max_len) -> cache pytree

    def abstract_params(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def abstract_cache(self, batch: int, max_len: int):
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len)
        )

    def input_specs(self, shape_name: str):
        """ShapeDtypeStruct stand-ins for one benchmark cell (no allocation)."""
        cfg = self.cfg
        spec = SHAPES[shape_name]
        b, s = spec["batch"], spec["seq"]
        f32 = jnp.float32
        i32 = jnp.int32
        sd = jax.ShapeDtypeStruct
        if spec["kind"] == "train":
            if cfg.family == "vlm":
                text = s - cfg.vlm_prefix
                return {
                    "patch_embeds": sd((b, cfg.vlm_prefix, cfg.d_model), f32),
                    "tokens": sd((b, text), i32),
                    "labels": sd((b, s), i32),
                }
            if cfg.family == "encdec":
                return {
                    "frames": sd((b, whisper.enc_len_for(cfg, s), cfg.d_model), f32),
                    "tokens": sd((b, s), i32),
                    "labels": sd((b, s), i32),
                }
            return {"tokens": sd((b, s), i32), "labels": sd((b, s), i32)}
        if spec["kind"] == "prefill":
            if cfg.family == "vlm":
                text = s - cfg.vlm_prefix
                return {
                    "patch_embeds": sd((b, cfg.vlm_prefix, cfg.d_model), f32),
                    "tokens": sd((b, text), i32),
                }
            if cfg.family == "encdec":
                return {
                    "frames": sd((b, whisper.enc_len_for(cfg, s), cfg.d_model), f32),
                    "tokens": sd((b, s), i32),
                }
            return {"tokens": sd((b, s), i32)}
        # decode: one new token against a cache of length s
        cache = jax.eval_shape(lambda: self.init_cache(b, s))
        return {
            "cache": cache,
            "token": sd((b, 1), i32),
            "pos": sd((), i32),
        }


def _ce_loss(logits, labels, n_valid=None):
    """Mean next-token cross-entropy, vocab-shard friendly.

    All vocab-axis work is reductions (max / sum-exp / masked-select-sum),
    so a model-sharded vocab axis needs only tiny (B,S) cross-shard
    all-reduces — never a full-logits gather.
    """
    lg = logits[:, :-1].astype(jnp.float32)
    tgt = labels[:, 1:]
    m = jnp.max(lg, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
    v = lg.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
    tgt_logit = jnp.sum(jnp.where(iota == tgt[..., None], lg, 0.0), axis=-1)
    return jnp.mean(lse - tgt_logit)


def build(cfg: ArchConfig, *, mesh=None, dp_axes=("data",),
          causal_skip: bool = False, block_specs=None) -> ModelApi:
    fam = cfg.family
    causal_skip = causal_skip or cfg.causal_skip

    if fam in ("dense", "mla", "moe", "vlm"):
        def init(key):
            return transformer.init_params(key, cfg)

        def forward(params, batch):
            return transformer.forward(
                params, cfg, batch["tokens"],
                prefix_embeds=batch.get("patch_embeds"),
                mesh=mesh, dp_axes=dp_axes, causal_skip=causal_skip,
                block_specs=block_specs,
            )

        def loss(params, batch):
            return _ce_loss(forward(params, batch), batch["labels"])

        def prefill(params, batch, max_len=None):
            # max_len counts *text* positions; the VLM patch prefix lives
            # in the same cache, so reserve room for it too — otherwise
            # the first decode write lands at index == cache length and
            # XLA clamps it onto the last prefill entry.
            if cfg.family == "vlm" and max_len is not None:
                max_len = max_len + cfg.vlm_prefix
            return transformer.prefill(
                params, cfg, batch["tokens"], max_len=max_len,
                prefix_embeds=batch.get("patch_embeds"), mesh=mesh,
                dp_axes=dp_axes,
            )

        def decode_step(params, cache, token, pos):
            return transformer.decode_step(params, cfg, cache, token, pos,
                                           mesh=mesh, dp_axes=dp_axes)

        def init_cache(batch, max_len):
            return transformer.init_cache(cfg, batch, max_len)

    elif fam == "ssm":
        def init(key):
            return xlstm.init_params(key, cfg)

        def forward(params, batch):
            return xlstm.forward(params, cfg, batch["tokens"])

        def loss(params, batch):
            return _ce_loss(forward(params, batch), batch["labels"])

        def prefill(params, batch, max_len=None):
            return xlstm.prefill(params, cfg, batch["tokens"], max_len=max_len)

        def decode_step(params, cache, token, pos):
            return xlstm.decode_step(params, cfg, cache, token, pos)

        def init_cache(batch, max_len):
            return xlstm.init_cache(cfg, batch, max_len)

    elif fam == "hybrid":
        def init(key):
            return hymba.init_params(key, cfg)

        def forward(params, batch):
            return hymba.forward(params, cfg, batch["tokens"], mesh=mesh,
                                 dp_axes=dp_axes, block_specs=block_specs)

        def loss(params, batch):
            return _ce_loss(forward(params, batch), batch["labels"])

        def prefill(params, batch, max_len=None):
            return hymba.prefill(params, cfg, batch["tokens"], max_len=max_len,
                                 mesh=mesh, dp_axes=dp_axes)

        def decode_step(params, cache, token, pos):
            return hymba.decode_step(params, cfg, cache, token, pos)

        def init_cache(batch, max_len):
            return hymba.init_cache(cfg, batch, max_len)

    elif fam == "encdec":
        def init(key):
            return whisper.init_params(key, cfg)

        def forward(params, batch):
            return whisper.forward(params, cfg, batch["tokens"],
                                   frames=batch["frames"], mesh=mesh,
                                   dp_axes=dp_axes, block_specs=block_specs)

        def loss(params, batch):
            return _ce_loss(forward(params, batch), batch["labels"])

        def prefill(params, batch, max_len=None):
            return whisper.prefill(params, cfg, batch["tokens"],
                                   frames=batch["frames"], max_len=max_len)

        def decode_step(params, cache, token, pos):
            return whisper.decode_step(params, cfg, cache, token, pos)

        def init_cache(batch, max_len):
            # decode cells: enc context = seq//4 per the shape convention
            return whisper.init_cache(cfg, batch, max_len,
                                      whisper.enc_len_for(cfg, max_len))

    else:
        raise ValueError(f"unknown family {fam}")

    return ModelApi(
        cfg=cfg, init=init, forward=forward, loss=loss, prefill=prefill,
        decode_step=decode_step, init_cache=init_cache,
    )


def applicable_shapes(cfg: ArchConfig):
    """The shape cells this arch runs (DESIGN.md §5: long_500k skips)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        names.append("long_500k")
    return names
