"""Whisper-style encoder-decoder backbone (whisper-base).

The conv/mel frontend is a STUB per the assignment: `input_specs()` supplies
precomputed frame embeddings (B, enc_len, d) directly (the post-conv 2x
downsampled mel features projected to d_model). Encoder: bidirectional
self-attention blocks; decoder: causal self-attention + cross-attention.
GELU MLPs as in the original (not SwiGLU). RoPE replaces the original
sinusoidal/learned positions (adaptation noted in DESIGN.md §7).

Shape convention (DESIGN.md §5): a cell with seq_len S maps to
enc_len = S // 4 frames and dec_len = S text tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.attention import decode_attention, flash_attention
from repro.models.config import ArchConfig


def enc_len_for(cfg: ArchConfig, seq_len: int) -> int:
    return max(seq_len // 4, 8)


def _gelu_mlp_init(key, d, ff):
    k1, k2 = jax.random.split(key)
    return {
        "w1": layers.uniform_init(k1, (d, ff)),
        "w2": layers.uniform_init(k2, (ff, d)),
    }


def _gelu_mlp(p, x):
    dt = x.dtype
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"].astype(dt)))
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(dt))


def _enc_block_init(key, cfg: ArchConfig):
    ka, kf = jax.random.split(key)
    return {
        "ln1": layers.rmsnorm_init(cfg.d_model),
        "attn": layers.gqa_proj_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim),
        "ln2": layers.rmsnorm_init(cfg.d_model),
        "mlp": _gelu_mlp_init(kf, cfg.d_model, cfg.d_ff),
    }


def _dec_block_init(key, cfg: ArchConfig):
    ka, kx, kf = jax.random.split(key, 3)
    return {
        "ln1": layers.rmsnorm_init(cfg.d_model),
        "self_attn": layers.gqa_proj_init(ka, cfg.d_model, cfg.n_heads,
                                          cfg.n_kv_heads, cfg.head_dim),
        "ln_x": layers.rmsnorm_init(cfg.d_model),
        "cross_attn": layers.gqa_proj_init(kx, cfg.d_model, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.head_dim),
        "ln2": layers.rmsnorm_init(cfg.d_model),
        "mlp": _gelu_mlp_init(kf, cfg.d_model, cfg.d_ff),
    }


def init_params(key, cfg: ArchConfig):
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": layers.embedding_init(ke, cfg.padded_vocab, cfg.d_model),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        "ln_enc": layers.rmsnorm_init(cfg.d_model),
        "ln_f": layers.rmsnorm_init(cfg.d_model),
    }


def encode(params, cfg: ArchConfig, frames, *, mesh=None, dp_axes=("data",),
           block_specs=None):
    """frames (B, Senc, d) from the frontend stub -> encoder states."""
    x = frames.astype(cfg.compute_dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    cos, sin = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta, positions)

    def body(h, p):
        h = layers.constrain_acts(h, mesh, dp_axes)
        p = layers.constrain_tree(p, block_specs, mesh)
        hn = layers.rmsnorm(p["ln1"], h, cfg.norm_eps)
        q, k, v = layers.qkv_project(p["attn"], hn, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim)
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
        a = flash_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
        h = h + layers.out_project(p["attn"], a)
        h = h + _gelu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], h, cfg.norm_eps))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.rmsnorm(params["ln_enc"], x, cfg.norm_eps)


def _cross_attend(p, cfg, hn, enc_out, enc_positions):
    """Cross-attention: queries from decoder, keys/values from encoder."""
    q, _, _ = layers.qkv_project(p, hn, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    dt = hn.dtype
    b, se, _ = enc_out.shape
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"].astype(dt)).reshape(
        b, se, cfg.n_kv_heads, cfg.head_dim
    )
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"].astype(dt)).reshape(
        b, se, cfg.n_kv_heads, cfg.head_dim
    )
    a = flash_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return layers.out_project(p, a)


def forward(params, cfg: ArchConfig, tokens, *, frames=None, mesh=None,
            dp_axes=("data",), block_specs=None, **_):
    """Training: frames (B, Senc, d) + text tokens (B, Sdec) -> logits."""
    assert frames is not None, "whisper training needs frame embeddings"
    enc_specs = (block_specs or {}).get("enc") if block_specs else None
    dec_specs = (block_specs or {}).get("dec") if block_specs else None
    enc_out = encode(params, cfg, frames, mesh=mesh, dp_axes=dp_axes,
                     block_specs=enc_specs)
    x = layers.embed(params["embed"], tokens, cfg.compute_dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    enc_positions = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
    cos, sin = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta, positions)

    def body(h, p):
        h = layers.constrain_acts(h, mesh, dp_axes)
        p = layers.constrain_tree(p, dec_specs, mesh)
        hn = layers.rmsnorm(p["ln1"], h, cfg.norm_eps)
        q, k, v = layers.qkv_project(p["self_attn"], hn, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim)
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
        a = flash_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        h = h + layers.out_project(p["self_attn"], a)
        hx = layers.rmsnorm(p["ln_x"], h, cfg.norm_eps)
        h = h + _cross_attend(p["cross_attn"], cfg, hx, enc_out, enc_positions)
        h = h + _gelu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], h, cfg.norm_eps))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return layers.unembed(params["embed"], x)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int):
    kvshape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    xshape = (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
    z = cfg.compute_dtype
    return {
        "k": jnp.zeros(kvshape, z), "v": jnp.zeros(kvshape, z),
        "xk": jnp.zeros(xshape, z), "xv": jnp.zeros(xshape, z),
    }


def prefill(params, cfg: ArchConfig, tokens, *, frames=None, max_len=None, **_):
    """Encode + run the decoder prompt. Returns (last logits, cache)."""
    enc_out = encode(params, cfg, frames)
    x = layers.embed(params["embed"], tokens, cfg.compute_dtype)
    b, s, _ = x.shape
    max_len = max(max_len or s, s)
    positions = jnp.arange(s, dtype=jnp.int32)
    enc_positions = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
    cos, sin = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta, positions)

    def body(h, p):
        hn = layers.rmsnorm(p["ln1"], h, cfg.norm_eps)
        q, k, v = layers.qkv_project(p["self_attn"], hn, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim)
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
        a = flash_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        h = h + layers.out_project(p["self_attn"], a)
        hx = layers.rmsnorm(p["ln_x"], h, cfg.norm_eps)
        # cross kv computed once, cached
        dt = h.dtype
        se = enc_out.shape[1]
        xk = jnp.einsum("bsd,dh->bsh", enc_out, p["cross_attn"]["wk"].astype(dt)
                        ).reshape(b, se, cfg.n_kv_heads, cfg.head_dim)
        xv = jnp.einsum("bsd,dh->bsh", enc_out, p["cross_attn"]["wv"].astype(dt)
                        ).reshape(b, se, cfg.n_kv_heads, cfg.head_dim)
        qx, _, _ = layers.qkv_project(p["cross_attn"], hx, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim)
        ax = flash_attention(qx, xk, xv, causal=False, chunk=cfg.attn_chunk)
        h = h + layers.out_project(p["cross_attn"], ax)
        h = h + _gelu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], h, cfg.norm_eps))
        pad = max_len - s
        kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return h, {"k": kk, "v": vv, "xk": xk, "xv": xv}

    x, cache = jax.lax.scan(body, x, params["dec_blocks"])
    x = layers.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    return layers.unembed(params["embed"], x), cache


def decode_step(params, cfg: ArchConfig, cache, token, pos):
    x = layers.embed(params["embed"], token, cfg.compute_dtype)
    posv = jnp.asarray(pos, jnp.int32)

    def body(h, scanned):
        p, lc = scanned
        hn = layers.rmsnorm(p["ln1"], h, cfg.norm_eps)
        q, k, v = layers.qkv_project(p["self_attn"], hn, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim)
        cos, sin = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta, posv[None])
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
        ck = jax.lax.dynamic_update_slice(lc["k"], k, (0, posv, 0, 0))
        cv = jax.lax.dynamic_update_slice(lc["v"], v, (0, posv, 0, 0))
        a = decode_attention(q, ck, cv, cache_len=posv + 1)
        h = h + layers.out_project(p["self_attn"], a)
        hx = layers.rmsnorm(p["ln_x"], h, cfg.norm_eps)
        qx, _, _ = layers.qkv_project(p["cross_attn"], hx, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim)
        ax = decode_attention(qx, lc["xk"], lc["xv"],
                              cache_len=lc["xk"].shape[1])
        h = h + layers.out_project(p["cross_attn"], ax)
        h = h + _gelu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], h, cfg.norm_eps))
        return h, {"k": ck, "v": cv, "xk": lc["xk"], "xv": lc["xv"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return layers.unembed(params["embed"], x), new_cache
