"""xLSTM (arXiv:2405.04517): mLSTM + sLSTM blocks, no FFN (xlstm-125m).

mLSTM blocks use the chunkwise-parallel matrix-memory recurrence
(models/ssm.py engine with the normalizer) — O(1) state per head, which is
why xlstm-125m runs the `long_500k` decode cell that full-attention archs
skip. sLSTM blocks (scalar memory + block-diagonal recurrent gate mixing)
are inherently sequential and run as a `lax.scan` over time.

Block layout follows the paper's 7:1 mLSTM:sLSTM ratio via
`slstm_layers` (default layers 5 and 11 of 12 are sLSTM).

Numerics adaptation (DESIGN.md §7): input/forget gates use log-sigmoid
(bounded <= 0) instead of the paper's exp-input-gate + running-max
stabilizer; the chunked engine then needs no stabilizer state. Parity
between the chunked and step forms is property-tested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ArchConfig
from repro.models.ssm import causal_conv1d, gla_chunked, gla_step

SLSTM_DEFAULT = (5, 11)


def slstm_layers(cfg: ArchConfig):
    return tuple(i for i in SLSTM_DEFAULT if i < cfg.n_layers)


def init_mlstm_block(key, cfg: ArchConfig):
    d = cfg.d_model
    di = 2 * d  # up-projection factor 2
    h = cfg.n_heads
    ks = jax.random.split(key, 9)
    return {
        "ln": layers.rmsnorm_init(d),
        "w_up": layers.uniform_init(ks[0], (d, di)),
        "w_z": layers.uniform_init(ks[1], (d, di)),
        "conv": layers.uniform_init(ks[2], (cfg.ssm_conv, di), scale=0.3),
        "wq": layers.uniform_init(ks[3], (di, di)),
        "wk": layers.uniform_init(ks[4], (di, di)),
        "wv": layers.uniform_init(ks[5], (di, di)),
        "w_gates": layers.uniform_init(ks[6], (di, 2 * h), scale=di**-0.5),
        "b_gates": jnp.concatenate(
            [jnp.zeros((h,), jnp.float32), 2.0 * jnp.ones((h,), jnp.float32)]
        ),  # forget-gate bias ~2: long memory at init
        "gn": layers.rmsnorm_init(di),
        "w_down": layers.uniform_init(ks[7], (di, d)),
    }


def _mlstm_qkv(p, cfg: ArchConfig, x, conv_state=None):
    """Shared train/decode projections. x (B, T, d)."""
    dt = x.dtype
    h = cfg.n_heads
    xn = layers.rmsnorm(p["ln"], x, cfg.norm_eps)
    xm = jnp.einsum("btd,de->bte", xn, p["w_up"].astype(dt))
    z = jnp.einsum("btd,de->bte", xn, p["w_z"].astype(dt))
    xc, conv_state = causal_conv1d(xm, p["conv"], state=conv_state)
    xc = jax.nn.silu(xc)
    di = xm.shape[-1]
    dh = di // h

    def heads(t):
        return t.reshape(t.shape[0], t.shape[1], h, dh).transpose(0, 2, 1, 3)

    q = heads(jnp.einsum("bte,ef->btf", xc, p["wq"].astype(dt)))
    k = heads(jnp.einsum("bte,ef->btf", xc, p["wk"].astype(dt))) * dh**-0.5
    v = heads(jnp.einsum("bte,ef->btf", xm, p["wv"].astype(dt)))
    gates = jnp.einsum("bte,eg->btg", xc, p["w_gates"].astype(dt)) + p[
        "b_gates"
    ].astype(dt)
    i_log = jax.nn.log_sigmoid(gates[..., :h].astype(jnp.float32))
    f_log = jax.nn.log_sigmoid(gates[..., h:].astype(jnp.float32))
    # (B, H, T) gate layout
    return q, k, v, i_log.transpose(0, 2, 1), f_log.transpose(0, 2, 1), z, conv_state


def mlstm_block(p, cfg: ArchConfig, x):
    """Train/prefill. x (B, S, d) -> (x + out, (S, n) final state)."""
    dt = x.dtype
    b, s, d = x.shape
    h = cfg.n_heads
    q, k, v, i_log, f_log, z, _ = _mlstm_qkv(p, cfg, x)
    y, state = gla_chunked(q, k, v, f_log, i_log, chunk=cfg.chunk, normalize=True)
    di = 2 * d
    y = y.transpose(0, 2, 1, 3).reshape(b, s, di)
    y = layers.rmsnorm(p["gn"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["w_down"].astype(dt))
    return x + out, state


def mlstm_decode(p, cfg: ArchConfig, x, cache):
    """One step. x (B, 1, d); cache {"s","n","conv"}."""
    dt = x.dtype
    b, _, d = x.shape
    h = cfg.n_heads
    q, k, v, i_log, f_log, z, conv_state = _mlstm_qkv(
        p, cfg, x, conv_state=cache["conv"]
    )
    y, (s_new, n_new) = gla_step(
        q[:, :, 0], k[:, :, 0], v[:, :, 0],
        f_log[:, :, 0], i_log[:, :, 0],
        (cache["s"], cache["n"]), normalize=True,
    )
    di = 2 * d
    y = y.reshape(b, 1, di)
    y = layers.rmsnorm(p["gn"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["w_down"].astype(dt))
    return x + out, {"s": s_new, "n": n_new, "conv": conv_state}


# --- sLSTM ------------------------------------------------------------------


def init_slstm_block(key, cfg: ArchConfig):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {
        "ln": layers.rmsnorm_init(d),
        "w_gates": layers.uniform_init(ks[0], (d, 4 * d)),  # i, f, z, o
        "r_gates": layers.uniform_init(ks[1], (4, h, dh, dh), scale=dh**-0.5),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "gn": layers.rmsnorm_init(d),
        "w_out": layers.uniform_init(ks[2], (d, d)),
    }


def slstm_block(p, cfg: ArchConfig, x, state=None):
    """Sequential sLSTM. x (B, S, d). state: dict(c, n, h) each (B, d)."""
    dt = x.dtype
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    xn = layers.rmsnorm(p["ln"], x, cfg.norm_eps)
    wx = jnp.einsum("btd,dg->btg", xn, p["w_gates"].astype(dt)) + p["b_gates"].astype(dt)
    if state is None:
        state = {
            "c": jnp.zeros((b, d), jnp.float32),
            "n": jnp.ones((b, d), jnp.float32),
            "h": jnp.zeros((b, d), jnp.float32),
        }
    r = p["r_gates"].astype(jnp.float32)

    def step(st, wx_t):
        hprev = st["h"].reshape(b, h, dh)
        rec = jnp.stack(
            [jnp.einsum("bhx,hxy->bhy", hprev, r[g]) for g in range(4)], axis=-2
        )  # (B, H, 4, dh)
        g = wx_t.astype(jnp.float32).reshape(b, h, 4, dh) + rec
        i = jnp.exp(jax.nn.log_sigmoid(g[..., 0, :]))
        f = jax.nn.sigmoid(g[..., 1, :])
        zz = jnp.tanh(g[..., 2, :])
        o = jax.nn.sigmoid(g[..., 3, :])
        c = f * st["c"].reshape(b, h, dh) + i * zz
        n = f * st["n"].reshape(b, h, dh) + i
        hh = o * c / jnp.maximum(n, 1.0)
        new = {"c": c.reshape(b, d), "n": n.reshape(b, d), "h": hh.reshape(b, d)}
        return new, hh.reshape(b, d)

    # time-major scan
    state, ys = jax.lax.scan(step, state, wx.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2).astype(dt)  # (B, S, d)
    y = layers.rmsnorm(p["gn"], y, cfg.norm_eps)
    out = jnp.einsum("btd,de->bte", y, p["w_out"].astype(dt))
    return x + out, state


# --- model ------------------------------------------------------------------


def init_params(key, cfg: ArchConfig):
    ke, kb = jax.random.split(key)
    bkeys = jax.random.split(kb, cfg.n_layers)
    sset = set(slstm_layers(cfg))
    blocks = [
        init_slstm_block(bkeys[i], cfg) if i in sset else init_mlstm_block(bkeys[i], cfg)
        for i in range(cfg.n_layers)
    ]
    return {
        "embed": layers.embedding_init(ke, cfg.padded_vocab, cfg.d_model),
        "blocks": blocks,  # heterogeneous: python list, not scanned
        "ln_f": layers.rmsnorm_init(cfg.d_model),
    }


def forward(params, cfg: ArchConfig, tokens, **_):
    x = layers.embed(params["embed"], tokens, cfg.compute_dtype)
    sset = set(slstm_layers(cfg))
    for i, bp in enumerate(params["blocks"]):
        if i in sset:
            x, _ = slstm_block(bp, cfg, x)
        else:
            x, _ = mlstm_block(bp, cfg, x)
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return layers.unembed(params["embed"], x)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Recurrent state — O(1) in max_len (the long_500k story)."""
    del max_len
    d = cfg.d_model
    h = cfg.n_heads
    di = 2 * d
    dh = di // h
    sset = set(slstm_layers(cfg))
    caches = []
    for i in range(cfg.n_layers):
        if i in sset:
            caches.append({
                "c": jnp.zeros((batch, d), jnp.float32),
                "n": jnp.ones((batch, d), jnp.float32),
                "h": jnp.zeros((batch, d), jnp.float32),
            })
        else:
            caches.append({
                "s": jnp.zeros((batch, h, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, h, dh), jnp.float32),
                "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), cfg.compute_dtype),
            })
    return caches


def prefill(params, cfg: ArchConfig, tokens, *, max_len=None, **_):
    """Run the prompt; returns (last-position logits, cache)."""
    x = layers.embed(params["embed"], tokens, cfg.compute_dtype)
    sset = set(slstm_layers(cfg))
    caches = []
    b = tokens.shape[0]
    for i, bp in enumerate(params["blocks"]):
        if i in sset:
            x, st = slstm_block(bp, cfg, x)
            caches.append(st)
        else:
            # carry conv tail + final (S, n)
            q = x
            x, (s_f, n_f) = mlstm_block(bp, cfg, x)
            dt = cfg.compute_dtype
            xn = layers.rmsnorm(bp["ln"], q, cfg.norm_eps)
            xm = jnp.einsum("btd,de->bte", xn, bp["w_up"].astype(dt))
            tail = xm[:, -(cfg.ssm_conv - 1):]
            pad = cfg.ssm_conv - 1 - tail.shape[1]
            if pad:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            caches.append({"s": s_f, "n": n_f, "conv": tail})
    x = layers.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    return layers.unembed(params["embed"], x), caches


def decode_step(params, cfg: ArchConfig, cache, token, pos):
    del pos  # recurrent: position-free
    x = layers.embed(params["embed"], token, cfg.compute_dtype)
    sset = set(slstm_layers(cfg))
    new_caches = []
    for i, bp in enumerate(params["blocks"]):
        if i in sset:
            x, st = slstm_block(bp, cfg, x, state=cache[i])
            new_caches.append(st)
        else:
            x, st = mlstm_decode(bp, cfg, x, cache[i])
            new_caches.append(st)
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return layers.unembed(params["embed"], x), new_caches
