"""Hymba (arXiv:2411.13676): hybrid-head blocks — parallel attention + SSM.

Each block runs GQA attention heads and Mamba2-style SSD heads *in
parallel* on the same input, fuses the branch outputs (per-branch RMSNorm +
learnable scalar betas, averaged), then a SwiGLU FFN. Sliding-window
attention everywhere except `global_layers` (full attention), plus
`meta_tokens` learnable prefix tokens that are always attendable.

Decode caches are heterogeneous per layer (ring buffer for SWA, full cache
for the few global layers, O(1) SSD + conv state), so the layer stack is a
Python loop rather than a scan. SWA + SSD state is why hymba runs the
long_500k decode cell: cache is O(window) + O(d_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.attention import decode_attention, flash_attention
from repro.models.config import ArchConfig
from repro.models.ssm import causal_conv1d, gla_chunked, gla_step


def _ssm_heads(cfg: ArchConfig):
    d_inner = cfg.d_model  # SSM branch width = d_model
    dh = cfg.head_dim
    return d_inner // dh, dh, d_inner


def init_block(key, cfg: ArchConfig):
    d = cfg.d_model
    n_ssm, dh, d_inner = _ssm_heads(cfg)
    ks = jax.random.split(key, 8)
    return {
        "ln_attn": layers.rmsnorm_init(d),
        "ln_ffn": layers.rmsnorm_init(d),
        "attn": layers.gqa_proj_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, dh),
        "ssm": {
            "w_in": layers.uniform_init(ks[1], (d, 2 * d_inner)),  # x + gate
            "conv": layers.uniform_init(ks[2], (cfg.ssm_conv, d_inner), scale=0.3),
            "w_bc": layers.uniform_init(ks[3], (d_inner, 2 * cfg.ssm_state)),
            "w_dt": layers.uniform_init(ks[4], (d_inner, n_ssm), scale=d**-0.5),
            "a_log": jnp.zeros((n_ssm,), jnp.float32),
            "d_skip": jnp.ones((n_ssm,), jnp.float32),
            "w_out": layers.uniform_init(ks[5], (d_inner, d)),
        },
        "norm_attn_out": layers.rmsnorm_init(d),
        "norm_ssm_out": layers.rmsnorm_init(d),
        "betas": jnp.ones((2,), jnp.float32),
        "ffn": layers.swiglu_init(ks[6], d, cfg.d_ff),
    }


def init_params(key, cfg: ArchConfig):
    ke, kb, km = jax.random.split(key, 3)
    bkeys = jax.random.split(kb, cfg.n_layers)
    return {
        "embed": layers.embedding_init(ke, cfg.padded_vocab, cfg.d_model),
        "meta": jax.random.normal(km, (cfg.meta_tokens, cfg.d_model), jnp.float32)
        * 0.02,
        # stacked (L, ...) — layers share structure; the SWA/global split is
        # data (a per-layer window value), so training scans one block body.
        "blocks": jax.vmap(lambda k: init_block(k, cfg))(bkeys),
        "ln_f": layers.rmsnorm_init(cfg.d_model),
    }


def layer_params(params, i: int):
    """Slice layer i out of the stacked blocks (prefill/decode loops)."""
    return jax.tree.map(lambda x: x[i], params["blocks"])


def window_schedule(cfg: ArchConfig):
    """Per-layer window values; 0 encodes full attention (global layers)."""
    return jnp.asarray(
        [0 if i in cfg.global_layers else cfg.window
         for i in range(cfg.n_layers)], jnp.int32,
    )


def _ssm_inputs(p, cfg: ArchConfig, xn, conv_state=None):
    """xn (B, T, d) -> gla inputs. Returns (q,k,v,a,b,gate,conv_state)."""
    dt = xn.dtype
    b, t, _ = xn.shape
    n_ssm, dh, d_inner = _ssm_heads(cfg)
    xz = jnp.einsum("btd,de->bte", xn, p["w_in"].astype(dt))
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = causal_conv1d(xs, p["conv"], state=conv_state)
    xs = jax.nn.silu(xs)
    bc = jnp.einsum("bte,en->btn", xs, p["w_bc"].astype(dt))
    bmat, cmat = jnp.split(bc, 2, axis=-1)  # (B, T, N) each
    dt_raw = jnp.einsum("bte,eh->bth", xs, p["w_dt"].astype(dt))  # (B, T, H)
    # decay a <= 0: -softplus(dt) * exp(a_log); input gate b <= 0: logsigmoid
    a = -jax.nn.softplus(dt_raw.astype(jnp.float32)) * jnp.exp(p["a_log"])
    bgate = jax.nn.log_sigmoid(dt_raw.astype(jnp.float32))
    # heads: v = head-split of xs; k = B shared across heads; q = C
    v = xs.reshape(b, t, n_ssm, dh).transpose(0, 2, 1, 3)  # (B, H, T, dh)
    k = jnp.broadcast_to(bmat[:, None], (b, n_ssm, t, cfg.ssm_state))
    q = jnp.broadcast_to(cmat[:, None], (b, n_ssm, t, cfg.ssm_state))
    return q, k, v, a.transpose(0, 2, 1), bgate.transpose(0, 2, 1), z, xs, conv_state


def _pad_ssm_heads(cfg, q, k, v, a, bg, mesh, dp_axes):
    """Pad the SSM head dim (axis 1) to cfg.ssm_pad_heads and shard it.

    hymba's 25 SSM heads don't divide a 16-way model axis, so GSPMD
    shards the *contracted* state dim instead — one all-reduce per chunk
    step of the recurrence (the dominant collective of the baseline
    prefill_32k cell). Padded heads get zero input gate (bg = -inf) and
    zero decay, so their state and output stay exactly 0; the extra
    compute (25 -> 32 heads) is 28% on the SSM branch, repaid 16x by an
    even head sharding.
    """
    hp = cfg.ssm_pad_heads
    h = q.shape[1]
    if hp <= h:
        return q, k, v, a, bg
    ph = hp - h

    def padh(x, value=0.0):
        widths = [(0, 0)] * x.ndim
        widths[1] = (0, ph)
        return jnp.pad(x, widths, constant_values=value)

    q, k, v = padh(q), padh(k), padh(v)
    a = padh(a)           # log-decay 0: no-op on a zero state
    bg = padh(bg, -1e30)  # input gate 0: state stays zero
    if mesh is not None and "model" in mesh.axis_names \
            and hp % mesh.shape["model"] == 0:
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = tuple(dp_axes)

        def cons(x):
            spec = [None] * x.ndim
            spec[1] = "model"
            ndp = 1
            for ax in dp:
                ndp *= mesh.shape[ax]
            if x.shape[0] % ndp == 0:
                spec[0] = dp
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))

        q, k, v, a, bg = cons(q), cons(k), cons(v), cons(a), cons(bg)
    return q, k, v, a, bg


def _ssm_branch(p, cfg: ArchConfig, xn, mesh=None, dp_axes=("data",)):
    dt = xn.dtype
    b, t, d = xn.shape
    n_ssm, dh, d_inner = _ssm_heads(cfg)
    q, k, v, a, bg, z, xs, _ = _ssm_inputs(p, cfg, xn)
    q, k, v, a, bg = _pad_ssm_heads(cfg, q, k, v, a, bg, mesh, dp_axes)
    y, _ = gla_chunked(q, k, v, a, bg, chunk=cfg.chunk)
    y = y[:, :n_ssm]  # drop padded heads (exact zeros)
    y = y.transpose(0, 2, 1, 3).reshape(b, t, d_inner)
    y = y + xs * jnp.repeat(p["d_skip"].astype(dt), dh)[None, None, :]
    y = y * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", y, p["w_out"].astype(dt))


def _fuse(p, cfg, attn_out, ssm_out):
    bet = p["betas"].astype(attn_out.dtype)
    a = layers.rmsnorm(p["norm_attn_out"], attn_out, cfg.norm_eps)
    s = layers.rmsnorm(p["norm_ssm_out"], ssm_out, cfg.norm_eps)
    return 0.5 * (bet[0] * a + bet[1] * s)


def _block(p, cfg: ArchConfig, x, positions, window, mesh=None,
           dp_axes=("data",)):
    """window: traced scalar; 0 means full attention (global layer)."""
    xn = layers.rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    q, k, v = layers.qkv_project(p["attn"], xn, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim)
    cos, sin = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta, positions)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    if cfg.attn_sharding == "qfull":
        q = layers.constrain_seq(q, mesh, dp_axes)
        k = layers.constrain_seq(k, mesh, dp_axes)
        v = layers.constrain_seq(v, mesh, dp_axes)
    attn_out = flash_attention(
        q, k, v, causal=True, window=window, chunk=cfg.attn_chunk,
        q_chunk=0 if cfg.attn_sharding == "qfull" else None,
        n_sink=cfg.meta_tokens)
    if cfg.attn_sharding == "qfull":
        attn_out = layers.constrain_seq(attn_out, mesh, dp_axes)
    attn_out = layers.out_project(p["attn"], attn_out)
    ssm_out = _ssm_branch(p["ssm"], cfg, xn, mesh=mesh, dp_axes=dp_axes)
    h = x + _fuse(p, cfg, attn_out, ssm_out)
    z = layers.rmsnorm(p["ln_ffn"], h, cfg.norm_eps)
    return h + layers.swiglu(p["ffn"], z)


def _with_meta(params, cfg, x):
    b = x.shape[0]
    meta = jnp.broadcast_to(
        params["meta"].astype(x.dtype)[None], (b, cfg.meta_tokens, cfg.d_model)
    )
    return jnp.concatenate([meta, x], axis=1)


def forward(params, cfg: ArchConfig, tokens, *, mesh=None, dp_axes=("data",),
            block_specs=None, **_):
    x = layers.embed(params["embed"], tokens, cfg.compute_dtype)
    x = _with_meta(params, cfg, x)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    wins = window_schedule(cfg)

    def body(h, scanned):
        bp, win = scanned
        h = layers.constrain_acts(h, mesh, dp_axes)
        bp = layers.constrain_tree(bp, block_specs, mesh)
        return _block(bp, cfg, h, positions, win, mesh=mesh,
                      dp_axes=dp_axes), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["blocks"], wins))
    x = x[:, cfg.meta_tokens :]
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return layers.unembed(params["embed"], x)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Per-layer: SWA ring (window) or full cache (global) + SSD/conv state."""
    n_ssm, dh, d_inner = _ssm_heads(cfg)
    caches = []
    for i in range(cfg.n_layers):
        lcache = (
            max_len + cfg.meta_tokens
            if i in cfg.global_layers
            else min(cfg.window + cfg.meta_tokens, max_len + cfg.meta_tokens)
        )
        caches.append({
            "k": jnp.zeros((batch, lcache, cfg.n_kv_heads, dh), cfg.compute_dtype),
            "v": jnp.zeros((batch, lcache, cfg.n_kv_heads, dh), cfg.compute_dtype),
            "s": jnp.zeros((batch, n_ssm, cfg.ssm_state, dh), jnp.float32),
            "n": jnp.zeros((batch, n_ssm, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), cfg.compute_dtype),
        })
    return caches


def prefill(params, cfg: ArchConfig, tokens, *, max_len=None, mesh=None,
            dp_axes=("data",), **_):
    x = layers.embed(params["embed"], tokens, cfg.compute_dtype)
    x = _with_meta(params, cfg, x)
    b, s, d = x.shape
    max_len = max_len or tokens.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    caches = []
    for i in range(cfg.n_layers):
        bp = layer_params(params, i)
        window = 0 if i in cfg.global_layers else cfg.window
        xn = layers.rmsnorm(bp["ln_attn"], x, cfg.norm_eps)
        q, k, v = layers.qkv_project(bp["attn"], xn, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim)
        cos, sin = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta, positions)
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
        if cfg.attn_sharding == "qfull":
            q = layers.constrain_seq(q, mesh, dp_axes)
            k = layers.constrain_seq(k, mesh, dp_axes)
            v = layers.constrain_seq(v, mesh, dp_axes)
        attn_out = flash_attention(
            q, k, v, causal=True, window=window, chunk=cfg.attn_chunk,
            q_chunk=0 if cfg.attn_sharding == "qfull" else None,
            n_sink=cfg.meta_tokens)
        if cfg.attn_sharding == "qfull":
            attn_out = layers.constrain_seq(attn_out, mesh, dp_axes)
        attn_out = layers.out_project(bp["attn"], attn_out)

        qg, kg, vg, a, bg, z, xs, conv_tail = _ssm_inputs(bp["ssm"], cfg, xn)
        n_ssm, dh, d_inner = _ssm_heads(cfg)
        qg, kg, vg, a, bg = _pad_ssm_heads(cfg, qg, kg, vg, a, bg, mesh,
                                           dp_axes)
        y, (s_f, n_f) = gla_chunked(qg, kg, vg, a, bg, chunk=cfg.chunk)
        y = y[:, :n_ssm]          # padded heads are exact zeros
        s_f = s_f[:, :n_ssm]
        n_f = n_f[:, :n_ssm]
        y = y.transpose(0, 2, 1, 3).reshape(b, s, d_inner)
        y = y + xs * jnp.repeat(bp["ssm"]["d_skip"].astype(x.dtype), dh)[None, None]
        y = y * jax.nn.silu(z)
        ssm_out = jnp.einsum("bte,ed->btd", y, bp["ssm"]["w_out"].astype(x.dtype))

        h = x + _fuse(bp, cfg, attn_out, ssm_out)
        zf = layers.rmsnorm(bp["ln_ffn"], h, cfg.norm_eps)
        x = h + layers.swiglu(bp["ffn"], zf)

        # build the cache entry
        lcache = (
            max_len + cfg.meta_tokens
            if i in cfg.global_layers
            else min(cfg.window + cfg.meta_tokens, max_len + cfg.meta_tokens)
        )
        if is_global_layer := (i in cfg.global_layers):
            if s >= lcache:
                ck, cv = k[:, :lcache], v[:, :lcache]
            else:
                pad = [(0, 0), (0, lcache - s), (0, 0), (0, 0)]
                ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
        else:
            # Ring layout: text position p lives at meta + (p - meta) % win,
            # meta tokens pinned at the front. Only the last `win` text
            # positions survive; their ring slots are unique.
            win = lcache - cfg.meta_tokens
            text_len = s - cfg.meta_tokens
            keep = min(win, text_len)
            ck = jnp.zeros((b, lcache) + k.shape[2:], k.dtype)
            cv = jnp.zeros_like(ck)
            ck = ck.at[:, : cfg.meta_tokens].set(k[:, : cfg.meta_tokens])
            cv = cv.at[:, : cfg.meta_tokens].set(v[:, : cfg.meta_tokens])
            p_kept = jnp.arange(s - keep, s)
            slots = cfg.meta_tokens + (p_kept - cfg.meta_tokens) % win
            ck = ck.at[:, slots].set(k[:, s - keep :])
            cv = cv.at[:, slots].set(v[:, s - keep :])
        # conv tail state
        tail = jnp.einsum(
            "btd,de->bte", xn, bp["ssm"]["w_in"].astype(x.dtype)
        )[..., :d_inner][:, -(cfg.ssm_conv - 1):]
        padn = cfg.ssm_conv - 1 - tail.shape[1]
        if padn:
            tail = jnp.pad(tail, ((0, 0), (padn, 0), (0, 0)))
        caches.append({"k": ck, "v": cv, "s": s_f, "n": n_f, "conv": tail})
    x = x[:, -1:]
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return layers.unembed(params["embed"], x), caches


def decode_step(params, cfg: ArchConfig, cache, token, pos):
    """pos: number of already-processed *text* tokens (cache validity)."""
    x = layers.embed(params["embed"], token, cfg.compute_dtype)
    posv = jnp.asarray(pos, jnp.int32) + cfg.meta_tokens
    new_caches = []
    for i in range(cfg.n_layers):
        bp = layer_params(params, i)
        is_global = i in cfg.global_layers
        lc = cache[i]
        lcache = lc["k"].shape[1]
        xn = layers.rmsnorm(bp["ln_attn"], x, cfg.norm_eps)
        q, k, v = layers.qkv_project(bp["attn"], xn, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim)
        cos, sin = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta, posv[None])
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
        # ring for SWA (meta tokens pinned at the front), append for global
        if is_global:
            slot = posv
        else:
            win = lcache - cfg.meta_tokens
            slot = cfg.meta_tokens + (posv - cfg.meta_tokens) % win
        ck = jax.lax.dynamic_update_slice(lc["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(lc["v"], v, (0, slot, 0, 0))
        nvalid = jnp.minimum(posv + 1, lcache)
        attn_out = decode_attention(q, ck, cv, cache_len=nvalid)
        attn_out = layers.out_project(bp["attn"], attn_out)

        qg, kg, vg, a, bg, z, xs, conv_state = _ssm_inputs(
            bp["ssm"], cfg, xn, conv_state=lc["conv"]
        )
        y, (s_new, n_new) = gla_step(
            qg[:, :, 0], kg[:, :, 0], vg[:, :, 0], a[:, :, 0], bg[:, :, 0],
            (lc["s"], lc["n"]),
        )
        n_ssm, dh, d_inner = _ssm_heads(cfg)
        b = x.shape[0]
        y = y.reshape(b, 1, d_inner)
        y = y + xs * jnp.repeat(bp["ssm"]["d_skip"].astype(x.dtype), dh)[None, None]
        y = y * jax.nn.silu(z)
        ssm_out = jnp.einsum("bte,ed->btd", y, bp["ssm"]["w_out"].astype(x.dtype))

        h = x + _fuse(bp, cfg, attn_out, ssm_out)
        zf = layers.rmsnorm(bp["ln_ffn"], h, cfg.norm_eps)
        x = h + layers.swiglu(bp["ffn"], zf)
        new_caches.append({"k": ck, "v": cv, "s": s_new, "n": n_new,
                           "conv": conv_state})
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return layers.unembed(params["embed"], x), new_caches
