"""JAX port of gensort (paper §3.2): deterministic sort-benchmark records.

The paper generates input with `gensort -c -b{offset} {size} {path}`:
records are reproducible from their global index alone, and a checksum
aggregated over all records validates end-to-end byte preservation.

Our record (DESIGN.md §2 key-width adaptation):
  key     : uint32 = splitmix32(global_id)   (uniform — Indy category)
  id      : uint32 = global_id               (the "rank"/provenance)
  payload : (PAYLOAD_WORDS,) uint32, word j = splitmix32(id * PW + j + SALT)

PAYLOAD_WORDS = 23 words = 92 bytes ≈ the 90-byte gensort payload, so
header+payload = 100 bytes/record exactly like the benchmark.

The checksum is order-independent (sum mod 2^32, xor) over per-record
hashes that cover key, id and payload — a reordering, duplication, or loss
of any record changes it, mirroring `gensort -c` / `valsort -s`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PAYLOAD_WORDS = 23  # 92 bytes; +8 header bytes = 100-byte records
_SALT = jnp.uint32(0x9E3779B9)


def splitmix32(x: jax.Array) -> jax.Array:
    """Fast avalanche hash; uint32 -> uint32 (fmix32 finalizer)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def gen_keys(start: int, n: int) -> tuple[jax.Array, jax.Array]:
    """Generate records [start, start+n): returns (keys, ids)."""
    ids = jnp.arange(start, start + n, dtype=jnp.uint32)
    return splitmix32(ids), ids


def gen_payload(ids: jax.Array, words: int = PAYLOAD_WORDS) -> jax.Array:
    """(n, words) uint32 payload rows, derivable from ids alone."""
    base = ids.astype(jnp.uint32)[:, None] * jnp.uint32(words)
    j = jnp.arange(words, dtype=jnp.uint32)[None, :]
    return splitmix32(base + j + _SALT)


def payload_hash(payload: jax.Array) -> jax.Array:
    """(n,) uint32 per-record hash of the payload words."""
    # Position-sensitive fold so word swaps are detected.
    j = jnp.arange(payload.shape[-1], dtype=jnp.uint32)[None, :]
    return jnp.sum(splitmix32(payload + j), axis=-1, dtype=jnp.uint32)


def record_hashes(keys: jax.Array, ids: jax.Array, payload: jax.Array | None = None):
    h = splitmix32(keys ^ splitmix32(ids))
    if payload is not None:
        h = splitmix32(h ^ payload_hash(payload))
    return h


def checksum(keys: jax.Array, ids: jax.Array, payload: jax.Array | None = None):
    """Order-independent (sum, xor) checksum over record hashes."""
    h = record_hashes(keys, ids, payload)
    s = jnp.sum(h, dtype=jnp.uint32)
    x = jax.lax.reduce(h, jnp.uint32(0), jax.lax.bitwise_xor, (0,))
    return s, x
