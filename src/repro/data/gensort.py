"""JAX port of gensort (paper §3.2): deterministic sort-benchmark records.

The paper generates input with `gensort -c -b{offset} {size} {path}`:
records are reproducible from their global index alone, and a checksum
aggregated over all records validates end-to-end byte preservation.

Our record (DESIGN.md §2 key-width adaptation):
  key     : uint32 = splitmix32(global_id)   (uniform — Indy category)
  id      : uint32 = global_id               (the "rank"/provenance)
  payload : (PAYLOAD_WORDS,) uint32, word j = splitmix32(id * PW + j + SALT)

PAYLOAD_WORDS = 23 words = 92 bytes ≈ the 90-byte gensort payload, so
header+payload = 100 bytes/record exactly like the benchmark.

The checksum is order-independent (sum mod 2^32, xor) over per-record
hashes that cover key, id and payload — a reordering, duplication, or loss
of any record changes it, mirroring `gensort -c` / `valsort -s`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PAYLOAD_WORDS = 23  # 92 bytes; +8 header bytes = 100-byte records
_SALT = jnp.uint32(0x9E3779B9)


def splitmix32(x: jax.Array) -> jax.Array:
    """Fast avalanche hash; uint32 -> uint32 (fmix32 finalizer)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def gen_keys(start: int, n: int) -> tuple[jax.Array, jax.Array]:
    """Generate records [start, start+n): returns (keys, ids)."""
    ids = jnp.arange(start, start + n, dtype=jnp.uint32)
    return splitmix32(ids), ids


def gen_payload(ids: jax.Array, words: int = PAYLOAD_WORDS) -> jax.Array:
    """(n, words) uint32 payload rows, derivable from ids alone."""
    base = ids.astype(jnp.uint32)[:, None] * jnp.uint32(words)
    j = jnp.arange(words, dtype=jnp.uint32)[None, :]
    return splitmix32(base + j + _SALT)


def payload_hash(payload: jax.Array) -> jax.Array:
    """(n,) uint32 per-record hash of the payload words."""
    # Position-sensitive fold so word swaps are detected.
    j = jnp.arange(payload.shape[-1], dtype=jnp.uint32)[None, :]
    return jnp.sum(splitmix32(payload + j), axis=-1, dtype=jnp.uint32)


def record_hashes(keys: jax.Array, ids: jax.Array, payload: jax.Array | None = None):
    h = splitmix32(keys ^ splitmix32(ids))
    if payload is not None:
        h = splitmix32(h ^ payload_hash(payload))
    return h


def checksum(keys: jax.Array, ids: jax.Array, payload: jax.Array | None = None):
    """Order-independent (sum, xor) checksum over record hashes."""
    h = record_hashes(keys, ids, payload)
    s = jnp.sum(h, dtype=jnp.uint32)
    x = jax.lax.reduce(h, jnp.uint32(0), jax.lax.bitwise_xor, (0,))
    return s, x


def combine_checksums(a: tuple[int, int], b: tuple[int, int]) -> tuple[int, int]:
    """Fold two partial (sum, xor) checksums — both ops are associative and
    order-independent, so streamed generation/validation can checksum the
    dataset one batch at a time (the `valsort -s` summary concatenation)."""
    return (int(a[0]) + int(b[0])) & 0xFFFFFFFF, int(a[1]) ^ int(b[1])


def write_to_store(
    store,
    bucket: str,
    prefix: str,
    total_records: int,
    records_per_partition: int,
    payload_words: int = PAYLOAD_WORDS,
    *,
    start_id: int = 0,
) -> tuple[tuple[int, int], int]:
    """Generate the benchmark input directly into an object store.

    The paper's `gensort -b{offset}` step (§3.2): partition p holds records
    [p * rpp, (p+1) * rpp), one io/records-encoded object per partition, so
    the out-of-core driver (core/external_sort.py) can stream them without
    the dataset ever existing in one memory. Returns the aggregate input
    checksum (the `gensort -c` sum) and the number of partitions written.
    """
    from repro.io import records as rec

    assert total_records % records_per_partition == 0
    num_parts = total_records // records_per_partition
    # Overwrite semantics: the prefix holds exactly this dataset afterwards
    # (stale partitions from a previous, larger run would otherwise be swept
    # into the sort and fail the checksum gate much later).
    for meta in store.list_objects(bucket, prefix):
        store.delete(bucket, meta.key)
    ck = (0, 0)
    for p in range(num_parts):
        keys, ids = gen_keys(start_id + p * records_per_partition,
                             records_per_partition)
        payload = gen_payload(ids, payload_words) if payload_words else None
        part_ck = checksum(keys, ids, payload)
        ck = combine_checksums(ck, (int(part_ck[0]), int(part_ck[1])))
        data = rec.encode_records(
            np.asarray(keys), np.asarray(ids),
            None if payload is None else np.asarray(payload),
        )
        store.put(bucket, f"{prefix}part-{p:05d}", data,
                  metadata={"records": records_per_partition})
    return ck, num_parts
