"""JAX port of gensort (paper §3.2): deterministic sort-benchmark records.

The paper generates input with `gensort -c -b{offset} {size} {path}`:
records are reproducible from their global index alone, and a checksum
aggregated over all records validates end-to-end byte preservation.

Our record (DESIGN.md §2 key-width adaptation):
  key     : uint32 = splitmix32(global_id)   (uniform — Indy category)
  id      : uint32 = global_id               (the "rank"/provenance)
  payload : (PAYLOAD_WORDS,) uint32, word j = splitmix32(id * PW + j + SALT)

PAYLOAD_WORDS = 23 words = 92 bytes ≈ the 90-byte gensort payload, so
header+payload = 100 bytes/record exactly like the benchmark.

The checksum is order-independent (sum mod 2^32, xor) over per-record
hashes that cover key, id and payload — a reordering, duplication, or loss
of any record changes it, mirroring `gensort -c` / `valsort -s`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PAYLOAD_WORDS = 23  # 92 bytes; +8 header bytes = 100-byte records
_SALT = jnp.uint32(0x9E3779B9)


def splitmix32(x: jax.Array) -> jax.Array:
    """Fast avalanche hash; uint32 -> uint32 (fmix32 finalizer)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def gen_keys(start: int, n: int) -> tuple[jax.Array, jax.Array]:
    """Generate records [start, start+n): returns (keys, ids)."""
    ids = jnp.arange(start, start + n, dtype=jnp.uint32)
    return splitmix32(ids), ids


#: Deterministic skewed key variants (the Daytona-style test fixtures —
#: gensort's -s "skewed keyspace" flag, adapted to the uint32 key):
#:   "hot"  — zipf-ish hot range: 7/8 of keys squeezed into the low
#:            2^24 span (key = u >> 8), the rest uniform.
#:   "zipf" — log-uniform magnitudes: key = u >> (h % 24), every
#:            octave [2^k, 2^{k+1}) carries ~equal mass, so low ranges
#:            are exponentially denser (pure-integer construction — no
#:            float pow, bit-identical everywhere).
#:   "clustered" — a handful of hot high-byte prefixes: keys land under
#:            4 seed-derived leading bytes (uniform low 24 bits), the
#:            "everyone's data starts with the same tenant id" shape.
#:   "dup"  — duplicate-heavy: every 4th record shares ONE hot key
#:            (seed-derived); no key-range split can separate them, so
#:            only a recursive round (re-shuffle by the next key bits,
#:            i.e. the id) can break the partition up.
SKEW_VARIANTS = ("hot", "zipf", "clustered", "dup")


def _splitmix32_np(x: np.ndarray) -> np.ndarray:
    # errstate: uint32 wraparound is the hash working as intended, but
    # numpy warns on overflow for 0-d (scalar) inputs.
    with np.errstate(over="ignore"):
        x = np.asarray(x, dtype=np.uint32)
        x = (x ^ (x >> np.uint32(16))) * np.uint32(0x85EBCA6B)
        x = (x ^ (x >> np.uint32(13))) * np.uint32(0xC2B2AE35)
        return x ^ (x >> np.uint32(16))


def skewed_keys(ids: np.ndarray, skew: str, seed: int = 0) -> np.ndarray:
    """Deterministic skewed key for each record id (host-side numpy).

    Same contract as `gen_keys`: the key is a pure function of
    (id, skew, seed), so any slice of the dataset can be regenerated
    independently and the checksum/valsort gates work unchanged — ids
    and payloads are untouched, only the key distribution changes.
    """
    if skew not in SKEW_VARIANTS:
        raise ValueError(
            f"skew={skew!r}: must be one of {SKEW_VARIANTS} (or None "
            "for the uniform Indy keys)")
    ids = np.asarray(ids, dtype=np.uint32)
    mix = _splitmix32_np(np.uint32(seed) ^ np.uint32(0xDECAFBAD))
    u = _splitmix32_np(ids ^ mix)
    if skew == "hot":
        return np.where(u % np.uint32(8) < np.uint32(7),
                        u >> np.uint32(8), u)
    if skew == "zipf":
        h = _splitmix32_np(u ^ np.uint32(0x5BD1E995))
        return u >> (h % np.uint32(24)).astype(np.uint32)
    if skew == "clustered":
        prefs = _splitmix32_np(
            np.uint32(mix) + np.arange(4, dtype=np.uint32)) >> np.uint32(24)
        sel = prefs[(u % np.uint32(4)).astype(np.int64)]
        return (sel.astype(np.uint32) << np.uint32(24)) | (
            _splitmix32_np(u) >> np.uint32(8))
    # "dup": one seed-derived hot key on a fixed id stride.
    hot = _splitmix32_np(mix ^ np.uint32(0x27220A95))
    return np.where(ids % np.uint32(4) == 0, hot, u)


def gen_payload(ids: jax.Array, words: int = PAYLOAD_WORDS) -> jax.Array:
    """(n, words) uint32 payload rows, derivable from ids alone."""
    base = ids.astype(jnp.uint32)[:, None] * jnp.uint32(words)
    j = jnp.arange(words, dtype=jnp.uint32)[None, :]
    return splitmix32(base + j + _SALT)


def payload_hash(payload: jax.Array) -> jax.Array:
    """(n,) uint32 per-record hash of the payload words."""
    # Position-sensitive fold so word swaps are detected.
    j = jnp.arange(payload.shape[-1], dtype=jnp.uint32)[None, :]
    return jnp.sum(splitmix32(payload + j), axis=-1, dtype=jnp.uint32)


def record_hashes(keys: jax.Array, ids: jax.Array, payload: jax.Array | None = None):
    h = splitmix32(keys ^ splitmix32(ids))
    if payload is not None:
        h = splitmix32(h ^ payload_hash(payload))
    return h


def checksum(keys: jax.Array, ids: jax.Array, payload: jax.Array | None = None):
    """Order-independent (sum, xor) checksum over record hashes."""
    h = record_hashes(keys, ids, payload)
    s = jnp.sum(h, dtype=jnp.uint32)
    x = jax.lax.reduce(h, jnp.uint32(0), jax.lax.bitwise_xor, (0,))
    return s, x


def combine_checksums(a: tuple[int, int], b: tuple[int, int]) -> tuple[int, int]:
    """Fold two partial (sum, xor) checksums — both ops are associative and
    order-independent, so streamed generation/validation can checksum the
    dataset one batch at a time (the `valsort -s` summary concatenation)."""
    return (int(a[0]) + int(b[0])) & 0xFFFFFFFF, int(a[1]) ^ int(b[1])


def write_to_store(
    store,
    bucket: str,
    prefix: str,
    total_records: int,
    records_per_partition: int,
    payload_words: int = PAYLOAD_WORDS,
    *,
    start_id: int = 0,
    skew: str | None = None,
    skew_seed: int = 0,
) -> tuple[tuple[int, int], int]:
    """Generate the benchmark input directly into an object store.

    The paper's `gensort -b{offset}` step (§3.2): partition p holds records
    [p * rpp, (p+1) * rpp), one io/records-encoded object per partition, so
    the out-of-core driver (core/external_sort.py) can stream them without
    the dataset ever existing in one memory. Returns the aggregate input
    checksum (the `gensort -c` sum) and the number of partitions written.

    `skew` selects a deterministic skewed key variant (SKEW_VARIANTS,
    seeded by `skew_seed`) instead of the uniform Indy keys — ids and
    payloads are unchanged, so the checksum/valsort gates apply as-is.
    """
    from repro.io import records as rec

    assert total_records % records_per_partition == 0
    if skew is not None and skew not in SKEW_VARIANTS:
        raise ValueError(
            f"skew={skew!r}: must be one of {SKEW_VARIANTS} or None")
    num_parts = total_records // records_per_partition
    # Overwrite semantics: the prefix holds exactly this dataset afterwards
    # (stale partitions from a previous, larger run would otherwise be swept
    # into the sort and fail the checksum gate much later).
    for meta in store.list_objects(bucket, prefix):
        store.delete(bucket, meta.key)
    ck = (0, 0)
    for p in range(num_parts):
        keys, ids = gen_keys(start_id + p * records_per_partition,
                             records_per_partition)
        if skew is not None:
            keys = jnp.asarray(
                skewed_keys(np.asarray(ids), skew, skew_seed))
        payload = gen_payload(ids, payload_words) if payload_words else None
        part_ck = checksum(keys, ids, payload)
        ck = combine_checksums(ck, (int(part_ck[0]), int(part_ck[1])))
        data = rec.encode_records(
            np.asarray(keys), np.asarray(ids),
            None if payload is None else np.asarray(payload),
        )
        store.put(bucket, f"{prefix}part-{p:05d}", data,
                  metadata={"records": records_per_partition})
    return ck, num_parts
