"""Training data pipeline with exoshuffle epoch shuffling.

The corpus is a deterministic synthetic token stream (like gensort for
text): token t of document i is derivable from (i, t) alone, so any worker
can materialize any slice without I/O — the CPU-container stand-in for a
sharded tokenized corpus.

Epoch shuffling is the paper's sort applied to data loading (DESIGN.md
§4.3): assign every sample the key splitmix32(epoch_seed ^ sample_id) and
(distributed-)sort — a uniform random key makes CloudSort's range partition
a perfect shuffle. On-device the exoshuffle path does this at pod scale
(`examples/cloudsort_e2e.py`); the host iterator below uses the same
construction with numpy for the training loop.

Sequence packing: `length_sorted_batches` sorts variable-length documents
by length (same sort machinery) so batches pad minimally.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.gensort import splitmix32


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    num_samples: int = 1 << 20


def _np_splitmix32(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32)
    x = (x ^ (x >> 16)) * np.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * np.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def shuffled_indices(epoch: int, n: int) -> np.ndarray:
    """The exoshuffle epoch permutation (host mirror of the device sort):
    sort sample ids by splitmix32(epoch_seed ^ id)."""
    ids = np.arange(n, dtype=np.uint32)
    keys = _np_splitmix32(ids ^ np.uint32(0x9E3779B9 * (epoch + 1) & 0xFFFFFFFF))
    return np.argsort(keys, kind="stable")


def sample_tokens(sample_ids: np.ndarray, seq_len: int, vocab: int) -> np.ndarray:
    """(n, seq_len+1) int32 tokens, deterministic in sample id."""
    n = sample_ids.shape[0]
    base = sample_ids.astype(np.uint32)[:, None] * np.uint32(seq_len + 1)
    t = np.arange(seq_len + 1, dtype=np.uint32)[None, :]
    return (_np_splitmix32(base + t) % np.uint32(vocab)).astype(np.int32)


class TokenPipeline:
    """Deterministic, restartable batch source.

    State is just (epoch, step) — restart after failure resumes the exact
    stream (the checkpoint stores the step; DESIGN.md §9 straggler note).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.steps_per_epoch = cfg.num_samples // cfg.global_batch
        self._epoch = -1
        self._perm = None

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        epoch = step // self.steps_per_epoch
        if epoch != self._epoch:
            self._perm = shuffled_indices(epoch, cfg.num_samples)
            self._epoch = epoch
        pos = (step % self.steps_per_epoch) * cfg.global_batch
        ids = self._perm[pos : pos + cfg.global_batch]
        toks = sample_tokens(ids, cfg.seq_len, cfg.vocab)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }


def length_sorted_batches(lengths: np.ndarray, batch: int) -> np.ndarray:
    """Sequence packing: batch ids grouped by sorted length (the same sort,
    keyed by document length). Returns (n//batch, batch) sample ids."""
    order = np.argsort(lengths.astype(np.uint32), kind="stable")
    n = (len(order) // batch) * batch
    return order[:n].reshape(-1, batch)


@functools.lru_cache(maxsize=None)
def _jitted_epoch_sort(mesh, axis_names, impl):
    from repro.core.exoshuffle import distributed_sort

    return jax.jit(
        lambda k, i: distributed_sort(k, i, mesh=mesh, axis_names=axis_names,
                                      impl=impl)
    )


def device_epoch_shuffle(sample_ids, epoch: int, *, mesh, axis_names, impl="ref"):
    """Pod-scale epoch shuffle via the actual exoshuffle distributed sort.

    sample_ids: (N,) uint32 sharded over axis_names. Returns the permuted
    ids as a (N,) host array — the valid prefix of each worker segment,
    concatenated in worker order (padding stripped).
    """
    from repro.data import valsort

    axis_names = (
        axis_names if isinstance(axis_names, str) else tuple(axis_names)
    )
    seed = jnp.uint32(0x9E3779B9 * (epoch + 1) & 0xFFFFFFFF)
    keys = splitmix32(sample_ids ^ seed)
    sort_fn = _jitted_epoch_sort(mesh, axis_names, impl)
    sk, si, counts, overflow = sort_fn(keys, sample_ids)
    if bool(np.asarray(overflow)):
        raise RuntimeError("epoch shuffle block overflow — raise capacity_factor")
    _, ids, _ = valsort.slice_segments(sk, si, counts)
    return np.concatenate(ids).astype(np.uint32)
