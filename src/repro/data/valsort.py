"""JAX/host port of valsort (paper §3.2): validate ordering + integrity.

The paper validates each output partition with `valsort -o`, concatenates
the per-partition summaries, checks the *total* ordering with `valsort -s`,
and compares the output checksum against the input checksum.

We reproduce the same three gates over the distributed sort's output:
  1. per-worker segment is lex-sorted (ascending by key, tie-broken by id);
  2. segment boundaries are non-decreasing (worker w's max <= w+1's min),
     which with (1) gives total ordering;
  3. the order-independent checksum of (key, id[, payload]) matches the
     input's — no record lost, duplicated, or corrupted.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data import gensort


@dataclasses.dataclass
class ValsortReport:
    total_records: int
    sorted_within: bool
    sorted_across: bool
    checksum_match: bool
    input_checksum: tuple[int, int]
    output_checksum: tuple[int, int]

    @property
    def ok(self) -> bool:
        return self.sorted_within and self.sorted_across and self.checksum_match


def validate(
    segments_keys: list[np.ndarray],
    segments_ids: list[np.ndarray],
    input_checksum: tuple[int, int],
    segments_payload: list[np.ndarray] | None = None,
) -> ValsortReport:
    """segments_*: per-worker valid output slices, in worker-range order."""
    sorted_within = True
    sorted_across = True
    prev_max = None
    for k, i in zip(segments_keys, segments_ids):
        if len(k) == 0:
            continue
        k64 = k.astype(np.uint64) << np.uint64(32) | i.astype(np.uint64)
        # NB: not np.diff >= 0 — unsigned subtraction wraps, so a descending
        # pair would still produce a "non-negative" difference.
        if not (k64[1:] >= k64[:-1]).all():
            sorted_within = False
        if prev_max is not None and k64[0] < prev_max:
            sorted_across = False
        prev_max = k64[-1]

    all_k = np.concatenate([np.asarray(s) for s in segments_keys])
    all_i = np.concatenate([np.asarray(s) for s in segments_ids])
    all_p = (
        np.concatenate([np.asarray(s) for s in segments_payload])
        if segments_payload is not None
        else None
    )
    import jax.numpy as jnp

    out_ck = gensort.checksum(
        jnp.asarray(all_k), jnp.asarray(all_i), None if all_p is None else jnp.asarray(all_p)
    )
    out_ck = (int(out_ck[0]), int(out_ck[1]))
    return ValsortReport(
        total_records=int(all_k.shape[0]),
        sorted_within=sorted_within,
        sorted_across=sorted_across,
        checksum_match=out_ck == tuple(int(c) for c in input_checksum),
        input_checksum=tuple(int(c) for c in input_checksum),
        output_checksum=out_ck,
    )


def validate_from_store(
    store,
    bucket: str,
    prefix: str,
    input_checksum: tuple[int, int],
    *,
    chunk_records: int = 1 << 13,
) -> ValsortReport:
    """Out-of-core valsort: stream output partitions from the object store.

    The paper validates each S3 output partition with `valsort -o` and the
    concatenated summaries with `valsort -s` (§3.2) — never holding the
    dataset in memory. Same here: partitions are read in `chunk_records`
    ranged GETs (request-accounted like any consumer), ordering is checked
    within partitions, across chunk boundaries, and across partition
    boundaries, and the order-independent checksum is folded incrementally
    (gensort.combine_checksums) against the input's.
    """
    from repro.data import gensort as _gensort
    from repro.io import records as rec

    objs = store.list_objects(bucket, prefix)
    sorted_within = True
    sorted_across = True
    total = 0
    out_ck = (0, 0)
    prev_last = None  # (key<<32 | id) of the previous record seen
    import jax.numpy as jnp

    for meta in objs:
        n, pw = rec.decode_header(store.get_range(bucket, meta.key, 0, rec.HEADER_BYTES))
        first_of_partition = True
        for lo in range(0, n, chunk_records):
            cnt = min(chunk_records, n - lo)
            start, length = rec.body_range(lo, cnt, pw)
            k, i, p = rec.decode_body(store.get_range(bucket, meta.key, start, length), pw)
            k64 = k.astype(np.uint64) << np.uint64(32) | i.astype(np.uint64)
            # Direct comparison, not np.diff >= 0: unsigned diff wraps.
            if not (k64[1:] >= k64[:-1]).all():
                sorted_within = False
            if prev_last is not None and len(k64) and k64[0] < prev_last:
                if first_of_partition:
                    sorted_across = False
                else:
                    sorted_within = False
            if len(k64):
                prev_last = k64[-1]
                first_of_partition = False
            ck = _gensort.checksum(
                jnp.asarray(k), jnp.asarray(i), None if p is None else jnp.asarray(p)
            )
            out_ck = _gensort.combine_checksums(out_ck, (int(ck[0]), int(ck[1])))
            total += cnt
    return ValsortReport(
        total_records=total,
        sorted_within=sorted_within,
        sorted_across=sorted_across,
        checksum_match=out_ck == tuple(int(c) for c in input_checksum),
        input_checksum=tuple(int(c) for c in input_checksum),
        output_checksum=out_ck,
    )


def slice_segments(sorted_keys, sorted_ids, counts, payload=None):
    """Split the flat global output of distributed_sort into valid segments."""
    sorted_keys = np.asarray(sorted_keys)
    sorted_ids = np.asarray(sorted_ids)
    counts = np.asarray(counts)
    w = counts.shape[0]
    seg = sorted_keys.shape[0] // w
    ks, ids, ps = [], [], []
    for d in range(w):
        lo, n = d * seg, int(counts[d])
        ks.append(sorted_keys[lo : lo + n])
        ids.append(sorted_ids[lo : lo + n])
        if payload is not None:
            ps.append(np.asarray(payload)[lo : lo + n])
    return (ks, ids, ps) if payload is not None else (ks, ids, None)
