"""Store backends: the data plane under the S3-contract interface.

The paper's shuffle treats "storage" as three different things (§2.2–§2.3):
durable object storage for input/output (S3 — high latency, per-request
fees, 503 throttling), local SSD for spilled runs (fast, free, dies with
the worker), and whatever a test harness wants (memory). PR 1 hard-wired
all of them to one filesystem class; this module splits the contract from
the implementation so the same external-sort driver can run against any
of them, and so the middleware stack (io/middleware.py) can inject the
S3 behaviours — latency, bandwidth, throttling, retries, accounting —
around *any* backend.

Layering:

  StoreBackend (ABC)   — the S3 surface the paper exercises. Subclasses
      implement only the primitives (create_bucket, multipart, get,
      get_range, head, list_objects, delete); `put`, `put_multipart`
      and `get_chunks` are derived on the base class in terms of the
      primitives, so a middleware that intercepts the primitives
      automatically covers the derived calls too.

  FilesystemBackend    — PR 1's filesystem emulation (persistent JSON
      manifests, atomic object replace, CRC32 etags), minus accounting
      (now MetricsMiddleware's job).

  MemoryBackend        — dict-backed store for tests and as the "local
      SSD" tier when tmpfs-like speed is wanted without touching disk.

Writes go through multipart *sessions* (`multipart()` -> MultipartUpload):
parts are *part-indexed* (`put_part(index, data)`) and may arrive in any
order from any number of threads — exactly S3's UploadPart contract, where
part numbers decide assembly order and the wire order is free. `complete()`
assembles parts in ascending index order and computes the CRC etag in that
part order, so an object uploaded 3,1,2 in parallel is byte- and
etag-identical to the same parts uploaded sequentially. This is what lets
the reduce pass fan one partition's part uploads out over a pool instead
of threading them through a single ordered queue (core/external_sort.py).

Thread-safe: the staging layer issues puts/gets from background threads
to overlap I/O with device compute (§2.5), and concurrent `put_part`
calls of one session race only on distinct part slots (same-index
re-uploads are last-write-wins, like S3).
"""
from __future__ import annotations

import abc
import dataclasses
import itertools
import json
import os
import threading
import zlib

try:  # POSIX advisory locks guard cross-process manifest updates.
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX fallback
    fcntl = None
from typing import Iterable, Iterator


class ObjectNotFound(KeyError):
    """Missing bucket or key (the S3 404)."""


class IntegrityError(RuntimeError):
    """Stored bytes do not match the manifest (size or CRC etag mismatch).

    A real error type, not an `assert` — corruption checks must survive
    `python -O`.
    """


class RetryableError(RuntimeError):
    """Transient store failure a client is expected to retry."""


class SlowDown(RetryableError):
    """S3 '503 Slow Down': request rate exceeded (io/middleware.py)."""


@dataclasses.dataclass
class StoreStats:
    """Cumulative request/byte counters — the measured Table-2 inputs.

    Request counters count *attempts issued*, so a GET that is throttled
    twice and then succeeds contributes 3 to `get_requests` — the
    retry-inflated count the cost model bills (an S3 retry is a new
    request). `throttled` / `retries` break the inflation out, and
    `stall_seconds` accumulates simulated network time injected by
    LatencyBandwidthMiddleware (summed across threads, so it can exceed
    wall time when requests overlap — that overhang is the overlap the
    staging layer hides).
    """

    get_requests: int = 0
    put_requests: int = 0
    head_requests: int = 0
    list_requests: int = 0
    delete_requests: int = 0  # free-tier priced, but tracked
    bytes_read: int = 0
    bytes_written: int = 0
    throttled: int = 0  # attempts rejected with SlowDown
    retries: int = 0  # re-issues performed by RetryMiddleware
    stall_seconds: float = 0.0  # simulated latency/bandwidth/backoff time

    def __post_init__(self):
        # One instance may be shared by several middleware layers writing
        # from staging threads; updates go through add()/snapshot() under
        # this lock (not a field — delta arithmetic below ignores it).
        object.__setattr__(self, "_lock", threading.Lock())

    def add(self, field: str, amount) -> None:
        """Atomic counter bump (thread-safe across sharing layers)."""
        with self._lock:
            setattr(self, field, getattr(self, field) + amount)

    def snapshot(self) -> "StoreStats":
        """Consistent copy of the counters (for before/after deltas)."""
        with self._lock:
            return StoreStats(**{
                f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
            })

    def __sub__(self, other: "StoreStats") -> "StoreStats":
        return StoreStats(**{
            f.name: getattr(self, f.name) - getattr(other, f.name)
            for f in dataclasses.fields(self)
        })

    def __add__(self, other: "StoreStats") -> "StoreStats":
        return StoreStats(**{
            f.name: getattr(self, f.name) + getattr(other, f.name)
            for f in dataclasses.fields(self)
        })


@dataclasses.dataclass(frozen=True)
class ObjectMeta:
    """Manifest entry: what `head` returns (S3 HeadObject)."""

    key: str
    size: int
    etag: str  # crc32 of the object bytes
    parts: int  # 1 for plain puts, #parts for multipart uploads
    metadata: dict


def _check_key(key: str) -> str:
    # Real exceptions, not asserts: the path-traversal guard must survive
    # `python -O` (a ".."-segment key would escape the bucket directory).
    if not key or key.startswith(("/", ".")) or ".." in key.split("/"):
        raise ValueError(f"bad object key {key!r}")
    return key


def _verify_integrity(where: str, data: bytes, entry: dict) -> bytes:
    """Whole-object read check shared by every backend: size and CRC etag
    must match the manifest, as real exceptions (survives `python -O`)."""
    if len(data) != entry["size"]:
        raise IntegrityError(
            f"{where}: size {len(data)} != manifest {entry['size']}")
    if f"{zlib.crc32(data):08x}" != entry["etag"]:
        raise IntegrityError(f"{where}: CRC mismatch vs etag")
    return data


class MultipartUpload(abc.ABC):
    """An in-progress multipart upload (S3 CreateMultipartUpload session).

    `put_part(index, data)` is the billable unit (one PUT per part,
    §3.3.2's "40 chunks" reduce upload); initiate/complete are free,
    matching the paper's request arithmetic. Part indices are the S3 part
    numbers: parts may be uploaded out of order and concurrently,
    re-uploading an index is last-write-wins, and `complete()` assembles
    ascending-by-index (gaps are fine, as on S3) with the CRC etag
    computed in that assembled order. Parts become visible atomically at
    `complete()`; `abort()` discards them — including parts whose upload
    raced the abort.
    """

    @abc.abstractmethod
    def put_part(self, index: int, data: bytes) -> None: ...

    @abc.abstractmethod
    def complete(self) -> ObjectMeta: ...

    @abc.abstractmethod
    def abort(self) -> None: ...


class StoreBackend(abc.ABC):
    """The S3 surface (paper §2.2): one store = one endpoint.

    Subclasses provide the primitives; `put` / `put_multipart` /
    `get_chunks` are derived here so every byte flows through the
    primitives (and therefore through any wrapping middleware) exactly
    once. Instances expose `chunk_size`, the default ranged-GET
    granularity.
    """

    # Annotation only (no class attr): middleware resolves chunk_size via
    # attribute delegation to the wrapped backend instance.
    chunk_size: int

    # -- primitives (implement in backends, intercept in middleware) -------

    @abc.abstractmethod
    def create_bucket(self, bucket: str) -> None: ...

    @abc.abstractmethod
    def multipart(self, bucket: str, key: str,
                  metadata: dict | None = None) -> MultipartUpload: ...

    @abc.abstractmethod
    def get(self, bucket: str, key: str) -> bytes: ...

    @abc.abstractmethod
    def get_range(self, bucket: str, key: str, start: int, length: int) -> bytes: ...

    @abc.abstractmethod
    def head(self, bucket: str, key: str) -> ObjectMeta: ...

    @abc.abstractmethod
    def list_objects(self, bucket: str, prefix: str = "") -> list[ObjectMeta]: ...

    @abc.abstractmethod
    def delete(self, bucket: str, key: str) -> None: ...

    # -- derived (never overridden by middleware) ---------------------------

    def put(self, bucket: str, key: str, data: bytes,
            metadata: dict | None = None) -> ObjectMeta:
        """S3 PutObject: one PUT request (a single-part session)."""
        mp = self.multipart(bucket, key, metadata)
        try:
            mp.put_part(0, bytes(data))
            return mp.complete()
        except BaseException:
            mp.abort()
            raise

    def put_multipart(self, bucket: str, key: str, parts: Iterable[bytes],
                      metadata: dict | None = None) -> ObjectMeta:
        """S3 multipart upload: one PUT request counted per part.

        `parts` may be a lazy iterable — each part streams to the backend
        as it is produced, so the whole object never has to exist in
        memory (the streaming reduce path).
        """
        mp = self.multipart(bucket, key, metadata)
        try:
            for idx, p in enumerate(parts):
                mp.put_part(idx, bytes(p))
            return mp.complete()
        except BaseException:
            mp.abort()
            raise

    def get_chunks(self, bucket: str, key: str,
                   chunk_size: int | None = None) -> Iterator[bytes]:
        """Download an object as ranged chunks — the paper's map download
        pattern (one GET per chunk, §3.3.2's "120 chunks" per map task).

        A zero-length object yields nothing and issues no GET, matching
        S3 (a ranged GET on an empty object is a 416, not a request a
        sane client pays for).
        """
        size = self.head(bucket, key).size
        step = int(chunk_size or self.chunk_size)
        assert step > 0
        for off in range(0, size, step):
            yield self.get_range(bucket, key, off, step)


# ---------------------------------------------------------------------------
# Filesystem backend (the PR-1 emulation, accounting removed)
# ---------------------------------------------------------------------------

_MANIFEST = "manifest.json"
_OBJECTS = "objects"


class FilesystemBackend(StoreBackend):
    """Buckets under `root`, objects as files, manifests as JSON.

    The manifest persists so a store can be reopened (the S3 namespace
    survives process death, unlike worker memory).

    Safe for concurrent writers in SEPARATE PROCESSES sharing one root:
    every manifest mutation is a read-modify-write of the on-disk JSON
    under an `fcntl` file lock, so two processes committing into the
    same bucket never lose each other's entries. The in-memory manifest
    is a cache of the disk state; reads reload it on a miss (an object
    another process put) and treat a vanished object file as a
    concurrent delete (`ObjectNotFound`) rather than a crash.
    """

    def __init__(self, root: str, *, chunk_size: int = 4 << 20):
        self.root = root
        self.chunk_size = int(chunk_size)
        self._lock = threading.Lock()
        self._manifests: dict[str, dict[str, dict]] = {}
        self._flush_locks: dict[str, threading.Lock] = {}
        os.makedirs(root, exist_ok=True)
        for bucket in sorted(os.listdir(root)):
            mpath = os.path.join(root, bucket, _MANIFEST)
            if os.path.isfile(mpath):
                with open(mpath) as f:
                    self._manifests[bucket] = json.load(f)
                self._flush_locks[bucket] = threading.Lock()

    # -- namespace ---------------------------------------------------------

    def create_bucket(self, bucket: str) -> None:
        os.makedirs(os.path.join(self.root, bucket, _OBJECTS), exist_ok=True)
        with self._lock:
            self._manifests.setdefault(bucket, {})
            self._flush_locks.setdefault(bucket, threading.Lock())
        # Merge-with-disk no-op: registers the bucket without clobbering
        # a manifest another process already populated.
        self._mutate_manifest(bucket, lambda manifest: None)

    def _object_path(self, bucket: str, key: str) -> str:
        return os.path.join(self.root, bucket, _OBJECTS, *_check_key(key).split("/"))

    def _bucket_known(self, bucket: str) -> bool:
        """True if the bucket exists here or was created by another
        process against the same root (registers it locally if so)."""
        if bucket in self._manifests:
            return True
        if not os.path.isdir(os.path.join(self.root, bucket, _OBJECTS)):
            return False
        with self._lock:
            self._manifests.setdefault(bucket, {})
            self._flush_locks.setdefault(bucket, threading.Lock())
        self._reload_manifest(bucket)
        return True

    def _mutate_manifest(self, bucket: str, fn) -> None:
        """Cross-process read-modify-write of the bucket manifest.

        The on-disk JSON is the source of truth: under an exclusive
        `fcntl` lock we load it, apply `fn(manifest)`, dump atomically,
        and refresh the in-memory cache. A per-bucket thread lock keeps
        same-process mutators from contending on the file lock."""
        mpath = os.path.join(self.root, bucket, _MANIFEST)
        lockpath = mpath + ".lock"
        with self._flush_locks[bucket]:
            with open(lockpath, "a") as lockf:
                if fcntl is not None:
                    fcntl.flock(lockf, fcntl.LOCK_EX)
                try:
                    try:
                        with open(mpath) as f:
                            manifest = json.load(f)
                    except (FileNotFoundError, json.JSONDecodeError):
                        manifest = {}
                    fn(manifest)
                    tmp = f"{mpath}.{os.getpid()}-{threading.get_ident()}.tmp"
                    with open(tmp, "w") as f:
                        json.dump(manifest, f)
                    os.replace(tmp, mpath)
                    with self._lock:
                        self._manifests[bucket] = manifest
                finally:
                    if fcntl is not None:
                        fcntl.flock(lockf, fcntl.LOCK_UN)

    def _reload_manifest(self, bucket: str) -> None:
        """Refresh the cached manifest from disk (another process may
        have committed since we last looked). Atomic `os.replace` on the
        writer side means we read a consistent snapshot or nothing."""
        mpath = os.path.join(self.root, bucket, _MANIFEST)
        try:
            with open(mpath) as f:
                fresh = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return
        with self._lock:
            self._manifests[bucket] = fresh

    def _entry(self, bucket: str, key: str) -> dict:
        try:
            return self._manifests[bucket][key]
        except KeyError:
            pass
        self._reload_manifest(bucket)
        try:
            return self._manifests[bucket][key]
        except KeyError:
            raise ObjectNotFound(f"{bucket}/{key}") from None

    @staticmethod
    def _meta(key: str, e: dict) -> ObjectMeta:
        return ObjectMeta(key=key, size=e["size"], etag=e["etag"],
                          parts=e["parts"], metadata=dict(e["metadata"]))

    # -- writes ------------------------------------------------------------

    def multipart(self, bucket: str, key: str,
                  metadata: dict | None = None) -> "_FsMultipart":
        if not self._bucket_known(bucket):
            raise ObjectNotFound(bucket)
        return _FsMultipart(self, bucket, key, metadata)

    def _commit(self, bucket: str, key: str, entry: dict) -> ObjectMeta:
        self._mutate_manifest(bucket,
                              lambda manifest: manifest.__setitem__(key, entry))
        return self._meta(key, entry)

    # -- reads -------------------------------------------------------------

    def _read_object(self, bucket: str, key: str, entry: dict, reader):
        """Run `reader(open file, entry)` surviving a concurrent
        cross-process delete: a vanished file means the cached entry was
        stale — reload, then either retry against the re-created object
        or report it gone."""
        path = self._object_path(bucket, key)
        try:
            with open(path, "rb") as f:
                return reader(f, entry)
        except FileNotFoundError:
            self._reload_manifest(bucket)
            fresh = self._manifests.get(bucket, {}).get(key)
            if fresh is None:
                raise ObjectNotFound(f"{bucket}/{key}") from None
            try:
                with open(path, "rb") as f:
                    return reader(f, fresh)
            except FileNotFoundError:
                raise ObjectNotFound(f"{bucket}/{key}") from None

    def get(self, bucket: str, key: str) -> bytes:
        """S3 GetObject (whole object), CRC-etag verified end to end."""
        def whole(f, e):
            return _verify_integrity(f"{bucket}/{key}", f.read(), e)
        return self._read_object(bucket, key, self._entry(bucket, key), whole)

    def get_range(self, bucket: str, key: str, start: int, length: int) -> bytes:
        """S3 ranged GET; truncates at object end like S3."""
        def ranged(f, e):
            lo = max(int(start), 0)
            f.seek(lo)
            return f.read(min(int(length), max(e["size"] - lo, 0)))
        return self._read_object(bucket, key, self._entry(bucket, key), ranged)

    # -- metadata ----------------------------------------------------------

    def head(self, bucket: str, key: str) -> ObjectMeta:
        return self._meta(key, self._entry(bucket, key))

    def list_objects(self, bucket: str, prefix: str = "") -> list[ObjectMeta]:
        if not self._bucket_known(bucket):
            raise ObjectNotFound(bucket)
        self._reload_manifest(bucket)  # see cross-process writes
        with self._lock:
            items = sorted(self._manifests[bucket].items())
        return [self._meta(k, e) for k, e in items if k.startswith(prefix)]

    def delete(self, bucket: str, key: str) -> None:
        self._entry(bucket, key)
        removed = []

        def drop(manifest):
            if manifest.pop(key, None) is not None:
                removed.append(key)

        # Manifest entry first, object file second: a reader holding a
        # stale cache either still finds the bytes (valid data) or hits
        # FileNotFoundError and resolves it via `_read_object`.
        self._mutate_manifest(bucket, drop)
        if not removed:
            raise ObjectNotFound(f"{bucket}/{key}")
        try:
            os.remove(self._object_path(bucket, key))
        except FileNotFoundError:
            pass


# Session nonces keep concurrent sessions for the same key from sharing
# tmp paths (the old thread-id scheme collided for same-thread sessions).
# The pid qualifier extends that to concurrent sessions in different
# processes — e.g. a speculative duplicate of a reduce task racing the
# original on the same output key.
_MP_NONCE = itertools.count()


class _FsMultipart(MultipartUpload):
    """Each part lands in its own tmp file (so concurrent out-of-order
    `put_part` calls never share a write path); `complete` streams the
    parts together ascending-by-index — CRC etag computed in that order,
    the S3 server-side assembly — and promotes the result atomically."""

    def __init__(self, backend: FilesystemBackend, bucket: str, key: str,
                 metadata: dict | None):
        self._b = backend
        self._bucket = bucket
        self._key = key
        self._metadata = dict(metadata or {})
        path = backend._object_path(bucket, key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        self._path = path
        self._tmp = f"{path}.{os.getpid()}-{next(_MP_NONCE)}.mp"
        self._lock = threading.Lock()
        # index -> (part tmp file, size, crc32): size/crc are computed at
        # upload time so a single-part complete() never re-reads the data.
        self._parts: dict[int, tuple[str, int, int]] = {}

    def _part_path(self, index: int) -> str:
        return f"{self._tmp}.part-{int(index):05d}"

    def put_part(self, index: int, data: bytes) -> None:
        index = int(index)
        if index < 0:
            raise ValueError(f"part index must be >= 0, got {index}")
        final = self._part_path(index)
        # Write-then-replace: a same-index re-upload is atomic last-write-
        # wins even when two uploaders race on the slot (S3 semantics).
        staged = f"{final}.{threading.get_ident()}.w"
        with open(staged, "wb") as f:
            f.write(data)
        os.replace(staged, final)
        with self._lock:
            self._parts[index] = (final, len(data), zlib.crc32(data))

    def complete(self) -> ObjectMeta:
        with self._lock:
            parts = sorted(self._parts.items())
        if len(parts) == 1:
            # Plain puts and single-part sessions — all spill and gensort
            # traffic — promote the part file directly: one disk write
            # total, no assembly copy or CRC re-read.
            _, (ppath, size, crc) = parts[0]
            os.replace(ppath, self._path)
        else:
            crc, size = 0, 0
            assembled = f"{self._tmp}.obj"
            with open(assembled, "wb") as out:
                for _, (ppath, _, _) in parts:
                    with open(ppath, "rb") as f:
                        data = f.read()
                    out.write(data)
                    crc = zlib.crc32(data, crc)
                    size += len(data)
            os.replace(assembled, self._path)
            for _, (ppath, _, _) in parts:
                if os.path.exists(ppath):
                    os.remove(ppath)
        entry = {"size": size, "etag": f"{crc:08x}",
                 "parts": max(len(parts), 1), "metadata": self._metadata}
        return self._b._commit(self._bucket, self._key, entry)

    def abort(self) -> None:
        # Sweep by registry AND by tmp-prefix glob: a put_part racing the
        # abort may have written its file but not yet registered it.
        with self._lock:
            paths = {p for p, _, _ in self._parts.values()}
            self._parts.clear()
        parent = os.path.dirname(self._tmp)
        prefix = os.path.basename(self._tmp)
        if os.path.isdir(parent):
            paths.update(os.path.join(parent, name)
                         for name in os.listdir(parent)
                         if name.startswith(prefix))
        for p in paths:
            try:
                os.remove(p)
            except FileNotFoundError:
                pass


# ---------------------------------------------------------------------------
# Memory backend (tests; also a zero-latency "local SSD" tier)
# ---------------------------------------------------------------------------


class MemoryBackend(StoreBackend):
    """Dict-backed store: same contract, no filesystem."""

    def __init__(self, *, chunk_size: int = 4 << 20):
        self.chunk_size = int(chunk_size)
        self._lock = threading.Lock()
        self._buckets: dict[str, dict[str, tuple[bytes, dict]]] = {}

    def create_bucket(self, bucket: str) -> None:
        with self._lock:
            self._buckets.setdefault(bucket, {})

    def _entry(self, bucket: str, key: str) -> tuple[bytes, dict]:
        try:
            return self._buckets[bucket][key]
        except KeyError:
            raise ObjectNotFound(f"{bucket}/{key}") from None

    def multipart(self, bucket: str, key: str,
                  metadata: dict | None = None) -> "_MemMultipart":
        if bucket not in self._buckets:
            raise ObjectNotFound(bucket)
        return _MemMultipart(self, bucket, _check_key(key), metadata)

    def get(self, bucket: str, key: str) -> bytes:
        data, e = self._entry(bucket, key)
        return _verify_integrity(f"{bucket}/{key}", data, e)

    def get_range(self, bucket: str, key: str, start: int, length: int) -> bytes:
        data, _ = self._entry(bucket, key)
        start = max(int(start), 0)
        return data[start : start + max(int(length), 0)]

    def head(self, bucket: str, key: str) -> ObjectMeta:
        _, e = self._entry(bucket, key)
        return ObjectMeta(key=key, size=e["size"], etag=e["etag"],
                          parts=e["parts"], metadata=dict(e["metadata"]))

    def list_objects(self, bucket: str, prefix: str = "") -> list[ObjectMeta]:
        if bucket not in self._buckets:
            raise ObjectNotFound(bucket)
        with self._lock:
            items = sorted(self._buckets[bucket].items())
        return [
            ObjectMeta(key=k, size=e["size"], etag=e["etag"], parts=e["parts"],
                       metadata=dict(e["metadata"]))
            for k, (_, e) in items if k.startswith(prefix)
        ]

    def delete(self, bucket: str, key: str) -> None:
        self._entry(bucket, key)
        with self._lock:
            del self._buckets[bucket][key]


class _MemMultipart(MultipartUpload):
    """Index-keyed part dict; `complete` joins ascending-by-index."""

    def __init__(self, backend: MemoryBackend, bucket: str, key: str,
                 metadata: dict | None):
        self._b = backend
        self._bucket = bucket
        self._key = key
        self._metadata = dict(metadata or {})
        self._lock = threading.Lock()
        self._parts: dict[int, bytes] = {}

    def put_part(self, index: int, data: bytes) -> None:
        index = int(index)
        if index < 0:
            raise ValueError(f"part index must be >= 0, got {index}")
        with self._lock:
            self._parts[index] = bytes(data)  # last-write-wins per slot

    def complete(self) -> ObjectMeta:
        with self._lock:
            parts = sorted(self._parts.items())
        data = b"".join(p for _, p in parts)
        entry = {"size": len(data), "etag": f"{zlib.crc32(data):08x}",
                 "parts": max(len(parts), 1), "metadata": self._metadata}
        with self._b._lock:
            self._b._buckets[self._bucket][self._key] = (data, entry)
        return ObjectMeta(key=self._key, size=entry["size"], etag=entry["etag"],
                          parts=entry["parts"], metadata=dict(self._metadata))

    def abort(self) -> None:
        with self._lock:
            self._parts.clear()
