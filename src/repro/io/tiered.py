"""Tiered store: local-SSD spill tier + durable (S3-like) tier (§2.3).

The paper's storage split that PR 1 glossed over: map outputs spill to
*local NVMe SSD* (fast, free, dies with the worker), while job input and
output live in *S3* (slow, throttled, billed per request). A TieredStore
routes by key prefix — spill keys to the SSD tier, everything else to the
durable tier — so the same external-sort driver exercises both cost
regimes, and the cost model can price the durable tier's requests alone
(core/cost_model.measured_tiered_cloudsort_tco) instead of billing spill
traffic as S3 traffic.

Both tiers are plain StoreBackends (usually metrics-wrapped, the durable
one usually fault-injected too); `per_tier_stats()` exposes each tier's
counters and `stats_snapshot()` their sum, so existing consumers that
expect one StoreStats delta keep working unchanged.

Multipart sessions route whole: `multipart(bucket, key)` returns the
owning tier's session directly, so part-indexed out-of-order parallel
part uploads (io/backends.MultipartUpload) flow through the tier's own
middleware stack — durable-tier parts are throttled/billed per part,
SSD-tier parts are free — with no extra layer in between.

How the external-sort plan knobs (core/external_sort.ExternalSortPlan)
split across the tiers:

  merge_chunk_bytes / reduce_memory_budget_bytes — reduce-side ranged
      GETs hit the SSD tier (spilled runs live under spill_prefix), so
      the budget governor's chunk sizing trades *SSD* request count
      against memory; it never changes the durable bill. The knobs'
      memory invariant (all-reducer decoded peak <= budget) is
      tier-independent.

  parallel_reducers / part_upload_fanout — output partitions are durable-
      tier multipart uploads: PUT attempts (and 503/retry inflation)
      scale with parallel_reducers x part_upload_fanout, and those are
      exactly the requests measured_tiered_cloudsort_tco bills. Spill
      PUTs from map workers stay on the free SSD tier at any fan-out.
"""
from __future__ import annotations

from typing import Iterable, Sequence

from repro.io.backends import (FilesystemBackend, MultipartUpload, ObjectMeta,
                               StoreBackend, StoreStats)
from repro.io.middleware import (FaultProfile, MetricsMiddleware, RetryPolicy,
                                 TracingMiddleware, fault_injected)
from repro.obs.events import Tracer


class TieredStore(StoreBackend):
    """Prefix-routed composition of an SSD tier and a durable tier.

    Keys under any of `ssd_prefixes` live in `ssd`; all other keys live
    in `durable`. `list_objects` merges the two namespaces (key-sorted)
    when the queried prefix spans both. A key can only ever live in one
    tier, so there is no shadowing to resolve.
    """

    def __init__(self, durable: StoreBackend, ssd: StoreBackend,
                 *, ssd_prefixes: Sequence[str] = ("spill/",)):
        self.durable = durable
        self.ssd = ssd
        self.ssd_prefixes = tuple(ssd_prefixes)
        assert all(self.ssd_prefixes), "empty ssd prefix would swallow every key"

    def _tier(self, key: str) -> StoreBackend:
        return self.ssd if key.startswith(self.ssd_prefixes) else self.durable

    @property
    def chunk_size(self) -> int:
        return self.durable.chunk_size

    # -- primitives, routed ------------------------------------------------

    def create_bucket(self, bucket: str) -> None:
        self.durable.create_bucket(bucket)
        self.ssd.create_bucket(bucket)

    def multipart(self, bucket: str, key: str,
                  metadata: dict | None = None) -> MultipartUpload:
        return self._tier(key).multipart(bucket, key, metadata)

    def get(self, bucket: str, key: str) -> bytes:
        return self._tier(key).get(bucket, key)

    def get_range(self, bucket: str, key: str, start: int, length: int) -> bytes:
        return self._tier(key).get_range(bucket, key, start, length)

    def head(self, bucket: str, key: str) -> ObjectMeta:
        return self._tier(key).head(bucket, key)

    def delete(self, bucket: str, key: str) -> None:
        self._tier(key).delete(bucket, key)

    def list_objects(self, bucket: str, prefix: str = "") -> list[ObjectMeta]:
        in_ssd = prefix.startswith(self.ssd_prefixes)
        metas: list[ObjectMeta] = []
        if not in_ssd:
            metas += self.durable.list_objects(bucket, prefix)
        if in_ssd or any(p.startswith(prefix) for p in self.ssd_prefixes):
            # Defensive filter: only surface keys that route to the SSD
            # tier, in case someone wrote foreign keys into it directly.
            metas += [m for m in self.ssd.list_objects(bucket, prefix)
                      if m.key.startswith(self.ssd_prefixes)]
        return sorted(metas, key=lambda m: m.key)

    # -- accounting --------------------------------------------------------

    def per_tier_stats(self) -> dict[str, StoreStats]:
        """{'durable': ..., 'ssd': ...} snapshots (zeros for an unmetered
        tier) — the separate legs the tiered cost model prices."""
        out = {}
        for name, tier in (("durable", self.durable), ("ssd", self.ssd)):
            snap = getattr(tier, "stats_snapshot", None)
            out[name] = snap() if snap else StoreStats()
        return out

    def stats_snapshot(self) -> StoreStats:
        """Sum over tiers — keeps single-StoreStats consumers working."""
        per = self.per_tier_stats()
        return per["durable"] + per["ssd"]


def tiered_cloudsort_store(
    root: str,
    *,
    spill_prefixes: Iterable[str] = ("spill/",),
    faults: FaultProfile | None = None,
    retry: RetryPolicy | None = None,
    chunk_size: int = 4 << 20,
    seed: int = 0,
    tracer: Tracer | None = None,
) -> TieredStore:
    """The paper's storage layout on one machine: a fault-injected durable
    tier at `root`/durable and a raw fast tier at `root`/ssd.

    With `faults=None` the durable tier is just metrics-wrapped (clean
    baseline for overlap benchmarks); otherwise it gets the full
    Retry(Metrics(Throttle(Latency(fs)))) stack (`retry` defaults to
    RetryPolicy() when faults are injected). The SSD tier is always
    metrics-only — local NVMe has neither request fees nor 503s. With a
    `tracer` (obs/events.Tracer) each tier also carries a
    TracingMiddleware, tier-labelled "durable" / "ssd", so every request
    attempt lands on the issuing task's trace as a tier-tagged child
    span.
    """
    import os

    durable_fs = FilesystemBackend(os.path.join(root, "durable"),
                                   chunk_size=chunk_size)
    if faults is None:
        durable: StoreBackend = MetricsMiddleware(durable_fs)
        if tracer is not None:
            durable = TracingMiddleware(durable, tracer, tier="durable")
    else:
        durable = fault_injected(
            durable_fs, profile=faults,
            retry=RetryPolicy() if retry is None else retry, seed=seed,
            tracer=tracer, tier="durable")
    ssd: StoreBackend = MetricsMiddleware(
        FilesystemBackend(os.path.join(root, "ssd"), chunk_size=chunk_size))
    if tracer is not None:
        ssd = TracingMiddleware(ssd, tracer, tier="ssd")
    return TieredStore(durable, ssd, ssd_prefixes=tuple(spill_prefixes))
