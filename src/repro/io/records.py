"""Record-block codec: (key, id, payload) arrays <-> object bytes.

The sort benchmark's unit of storage is the 100-byte record (§2.2): a
10-byte key plus 90-byte payload, laid out *interleaved* so that any
contiguous record range of an object maps to one contiguous byte range —
which is what lets the reduce pass fetch exactly its reducer's slice of a
spilled run with a single S3 ranged GET (core/external_sort.py).

Our record (DESIGN.md §2 key-width adaptation, as in data/gensort.py):

  row = [key: u32][id: u32][payload: u32 x payload_words]   little-endian

An encoded object is a 16-byte header (magic, version, n_records,
payload_words) followed by n_records interleaved rows. `body_range`
computes the byte range of a record slice so callers never re-derive the
layout; `decode_body` parses a headerless ranged-GET response.
"""
from __future__ import annotations

import numpy as np

MAGIC = 0x58535254  # "XSRT"
VERSION = 1
HEADER_BYTES = 16


def record_bytes(payload_words: int) -> int:
    """Bytes per interleaved record row."""
    return 4 * (2 + int(payload_words))


def encode_header(n_records: int, payload_words: int) -> bytes:
    """The HEADER_BYTES prefix of an encoded object. Split out from
    encode_records so a streaming writer that knows its final record
    count up front (the reduce merge does — it's the sum of its run-slice
    lengths) can emit the header first and append body chunks as they are
    merged, never materializing the object."""
    return np.array([MAGIC, VERSION, int(n_records), int(payload_words)],
                    dtype="<u4").tobytes()


def encode_body(keys, ids, payload=None) -> bytes:
    """Interleaved rows only (no header) — one streamable body chunk."""
    keys = np.ascontiguousarray(keys, dtype=np.uint32)
    ids = np.ascontiguousarray(ids, dtype=np.uint32)
    n = keys.shape[0]
    assert ids.shape == (n,)
    pw = 0 if payload is None else int(payload.shape[-1])
    rows = np.empty((n, 2 + pw), dtype="<u4")
    rows[:, 0] = keys
    rows[:, 1] = ids
    if pw:
        assert payload.shape == (n, pw)
        rows[:, 2:] = np.asarray(payload, dtype=np.uint32)
    return rows.tobytes()


def encode_records(keys, ids, payload=None) -> bytes:
    """Pack records into one object. keys/ids (n,) u32; payload (n, pw) u32
    or None (header-only records, pw=0)."""
    pw = 0 if payload is None else int(payload.shape[-1])
    return encode_header(len(keys), pw) + encode_body(keys, ids, payload)


def decode_header(data: bytes) -> tuple[int, int]:
    """(n_records, payload_words) from the first HEADER_BYTES of an object."""
    magic, version, n, pw = np.frombuffer(data[:HEADER_BYTES], dtype="<u4")
    if magic != MAGIC or version != VERSION:
        # ValueError, not assert: the format gate must survive python -O.
        raise ValueError("not an XSRT record object")
    return int(n), int(pw)


def decode_records(data: bytes):
    """Inverse of encode_records: (keys, ids, payload|None)."""
    n, pw = decode_header(data)
    body = data[HEADER_BYTES : HEADER_BYTES + n * record_bytes(pw)]
    return decode_body(body, pw)


def decode_body(body: bytes, payload_words: int):
    """Parse headerless interleaved rows (a ranged-GET response)."""
    pw = int(payload_words)
    rb = record_bytes(pw)
    assert len(body) % rb == 0, (len(body), rb)
    rows = np.frombuffer(body, dtype="<u4").reshape(-1, 2 + pw)
    keys = rows[:, 0].astype(np.uint32)
    ids = rows[:, 1].astype(np.uint32)
    payload = rows[:, 2:].astype(np.uint32) if pw else None
    return keys, ids, payload


def body_range(start_record: int, n_records: int, payload_words: int):
    """(byte_offset, byte_length) of records [start, start+n) within an
    encoded object — the ranged-GET window for a run slice."""
    rb = record_bytes(payload_words)
    return HEADER_BYTES + int(start_record) * rb, int(n_records) * rb


# ---------------------------------------------------------------------------
# Zero-copy wave assembly: decode chunk streams straight into one buffer
# ---------------------------------------------------------------------------


def alloc_rows(n_records: int, payload_words: int) -> np.ndarray:
    """One preallocated interleaved-row buffer for `n_records` records —
    the target StreamDecoder fills and split_rows views into."""
    return np.empty((int(n_records), 2 + int(payload_words)), dtype="<u4")


def split_rows(rows: np.ndarray):
    """(keys, ids, payload|None) *views* into an interleaved rows buffer —
    no copy; the row storage stays the single owner of the bytes."""
    pw = rows.shape[1] - 2
    return rows[:, 0], rows[:, 1], (rows[:, 2:] if pw else None)


class StreamDecoder:
    """Decode one encoded object's chunk stream straight into a rows buffer.

    The zero-copy map download path (core/external_sort.py): instead of
    `b"".join(chunks)` + decode + `np.concatenate` across objects — three
    full copies of every wave byte — each ranged-GET chunk is copied once,
    directly into its final position in a preallocated `alloc_rows` buffer
    at `start_record`. Works for any chunking: record and header
    boundaries may fall anywhere inside or across chunks.

    Feed chunks in object-byte order (`feed`), then `finish()` — which
    validates the object header (magic/version, record count against the
    records actually written, payload width against the buffer's) and
    returns the record count.
    """

    def __init__(self, rows: np.ndarray, start_record: int = 0,
                 *, what: str = "object"):
        pw = rows.shape[1] - 2
        self._rb = record_bytes(pw)
        self._pw = pw
        self._what = what
        self._header = bytearray()
        if not rows.flags.c_contiguous:
            raise ValueError("rows buffer must be C-contiguous")
        self._dest = memoryview(rows).cast("B")
        self._off = int(start_record) * self._rb
        self._start = self._off

    def feed(self, chunk: bytes) -> None:
        view = memoryview(chunk)
        if len(self._header) < HEADER_BYTES:  # header may span chunks
            take = min(HEADER_BYTES - len(self._header), len(view))
            self._header += view[:take]
            view = view[take:]
        if len(view):
            end = self._off + len(view)
            if end > len(self._dest):
                raise ValueError(
                    f"{self._what}: body overflows the rows buffer "
                    f"(byte {end} > {len(self._dest)})")
            self._dest[self._off:end] = view
            self._off = end

    def finish(self) -> int:
        if len(self._header) < HEADER_BYTES:
            raise ValueError(f"{self._what}: truncated header "
                             f"({len(self._header)} bytes)")
        n, pw = decode_header(bytes(self._header))
        written, want = self._off - self._start, n * self._rb
        if pw != self._pw:
            raise ValueError(f"{self._what}: payload_words={pw}, "
                             f"buffer expects {self._pw}")
        if written != want:
            raise ValueError(f"{self._what}: body is {written} bytes, "
                             f"header promises {want}")
        return n
