"""Async double-buffered host<->device staging (paper §2.5 pipelining).

The paper gets map-download / shuffle / merge / reduce-upload overlap "for
free" from Ray's pipelined task execution: while a map task sorts block r,
the next input block r+1 is already downloading, and finished merge runs
upload while compute continues. Inside a round the XLA latency-hiding
scheduler overlaps collectives with compute (core/streaming.py); *between*
the store and the device there is no scheduler, so this module supplies the
overlap explicitly:

  prefetch(thunks, depth)  — double-buffered reader: keeps `depth` store
      reads in flight ahead of the consumer, so wave g+1's chunked GETs
      (io/backends.get_chunks) run while wave g is being sorted. Optionally
      retry-aware: transient store failures (e.g. a SlowDown that escaped
      a store-level RetryMiddleware) are re-issued with backoff instead of
      killing the pipeline.

  AsyncWriter(max_inflight) — bounded write-behind for spills/uploads.
      `submit` blocks once `max_inflight` writes are pending — the static
      analogue of the paper's merge controller withholding acks to
      back-pressure producers (§2.3) — so host memory holds at most
      max_inflight encoded runs. Multipart part uploads are part-indexed
      (io/backends.put_part(index, data)), so a multi-worker pool may
      complete them out of order and the assembled object is still exact;
      max_workers=1 remains available for genuinely order-sensitive
      submissions (it executes strictly in submission order).

Both are plain thread pools: store I/O is file I/O + numpy codec work that
releases the GIL, and device compute runs inside jit, so the overlap is
real even on CPU backends.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Iterable, Iterator, Type, TypeVar

from repro.obs.context import current_context, use_context

T = TypeVar("T")


def prefetch(
    thunks: Iterable[Callable[[], T]],
    depth: int = 2,
    *,
    retries: int = 0,
    retry_on: tuple[Type[BaseException], ...] = (),
    retry_delay_s: float = 0.05,
) -> Iterator[T]:
    """Yield thunk() results in order with up to `depth` reads in flight.

    Double buffering is depth=2: one result being consumed, one loading.
    Exceptions from a thunk surface at the corresponding yield; unconsumed
    work is cancelled when the generator is closed.

    With `retries` > 0, a thunk that raises one of `retry_on` is re-run
    in place (exponential backoff from `retry_delay_s`) up to `retries`
    times before the error surfaces — so a transient store stall costs a
    delay, not the whole wave pipeline.
    """
    assert depth >= 1
    assert retries >= 0

    def attempt(thunk: Callable[[], T]) -> T:
        for k in range(retries + 1):
            try:
                return thunk()
            except retry_on:
                if k == retries:
                    raise
                time.sleep(retry_delay_s * (2.0 ** k))
        raise AssertionError("unreachable")

    run = attempt if retries and retry_on else (lambda thunk: thunk())
    ex = ThreadPoolExecutor(max_workers=depth, thread_name_prefix="stage-read")
    it = iter(thunks)
    pending: collections.deque[Future] = collections.deque()
    try:
        exhausted = False
        while True:
            while not exhausted and len(pending) < depth:
                try:
                    pending.append(ex.submit(run, next(it)))
                except StopIteration:
                    exhausted = True
            if not pending:
                return
            yield pending.popleft().result()
    finally:
        for f in pending:
            f.cancel()
        ex.shutdown(wait=True, cancel_futures=True)


class AsyncWriter:
    """Bounded write-behind queue for store puts (spill / output upload).

    max_inflight bounds how many submissions may be pending (backpressure);
    max_workers (default = max_inflight) is the pool width. max_workers=1
    gives strict FIFO execution for order-sensitive submissions; part-
    indexed multipart uploads (put_part(index, data)) don't need it — the
    reduce path fans parts out over max_workers=part_upload_fanout.
    """

    def __init__(self, max_inflight: int = 2, *, max_workers: int | None = None,
                 thread_name_prefix: str = "stage-write"):
        assert max_inflight >= 1
        self._ex = ThreadPoolExecutor(
            max_workers=max_workers or max_inflight,
            thread_name_prefix=thread_name_prefix,
        )
        self._slots = threading.Semaphore(max_inflight)
        self._futures: list[Future] = []
        self._exc_lock = threading.Lock()
        self._first_exc: BaseException | None = None

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        """Queue fn(*args); blocks while `max_inflight` writes are pending
        (backpressure — the merge-controller ack analogue)."""
        self._slots.acquire()
        # TraceContexts don't cross thread pools on their own: capture the
        # submitter's context here so the write's store requests are
        # attributed to the task that queued them, not the pool thread.
        ctx = current_context()

        def run():
            try:
                with use_context(ctx):
                    return fn(*args, **kwargs)
            except BaseException as e:
                # Record the *chronologically first* failure: with several
                # writer threads, the future list's order is submission
                # order, not failure order, and the root cause is whichever
                # upload broke first (later ones often fail as fallout).
                with self._exc_lock:
                    if self._first_exc is None:
                        self._first_exc = e
                raise
            finally:
                self._slots.release()

        f = self._ex.submit(run)
        self._futures.append(f)
        return f

    @property
    def failed(self) -> bool:
        """True once any submitted write has raised (drain will re-raise
        it). Lets order-dependent consumers — e.g. the task that would
        commit a multipart upload after its part uploads — turn a
        completed-but-broken pipeline into an abort instead."""
        with self._exc_lock:
            return self._first_exc is not None

    def drain(self) -> None:
        """Wait for all pending writes; re-raises the first failure (by
        failure time) with its original traceback."""
        futures, self._futures = self._futures, []
        for f in futures:
            f.exception()  # wait without raising; first_exc decides below
        with self._exc_lock:
            exc, self._first_exc = self._first_exc, None
        if exc is not None:
            raise exc

    def close(self) -> None:
        try:
            self.drain()
        finally:  # never leak the worker thread, even when drain raises
            self._ex.shutdown(wait=True)

    def __enter__(self) -> "AsyncWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:  # don't mask the in-flight exception; just stop the pool
            self._ex.shutdown(wait=True, cancel_futures=True)
