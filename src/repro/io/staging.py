"""Async double-buffered host<->device staging (paper §2.5 pipelining).

The paper gets map-download / shuffle / merge / reduce-upload overlap "for
free" from Ray's pipelined task execution: while a map task sorts block r,
the next input block r+1 is already downloading, and finished merge runs
upload while compute continues. Inside a round the XLA latency-hiding
scheduler overlaps collectives with compute (core/streaming.py); *between*
the store and the device there is no scheduler, so this module supplies the
overlap explicitly:

  prefetch(thunks, depth)  — double-buffered reader: keeps `depth` store
      reads in flight ahead of the consumer, so wave g+1's chunked GETs
      (io/object_store.get_chunks) run while wave g is being sorted.

  AsyncWriter(max_inflight) — bounded write-behind for spills/uploads.
      `submit` blocks once `max_inflight` writes are pending — the static
      analogue of the paper's merge controller withholding acks to
      back-pressure producers (§2.3) — so host memory holds at most
      max_inflight encoded runs.

Both are plain thread pools: store I/O is file I/O + numpy codec work that
releases the GIL, and device compute runs inside jit, so the overlap is
real even on CPU backends.
"""
from __future__ import annotations

import collections
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Iterable, Iterator, TypeVar

T = TypeVar("T")


def prefetch(thunks: Iterable[Callable[[], T]], depth: int = 2) -> Iterator[T]:
    """Yield thunk() results in order with up to `depth` reads in flight.

    Double buffering is depth=2: one result being consumed, one loading.
    Exceptions from a thunk surface at the corresponding yield; unconsumed
    work is cancelled when the generator is closed.
    """
    assert depth >= 1
    ex = ThreadPoolExecutor(max_workers=depth, thread_name_prefix="stage-read")
    it = iter(thunks)
    pending: collections.deque[Future] = collections.deque()
    try:
        exhausted = False
        while True:
            while not exhausted and len(pending) < depth:
                try:
                    pending.append(ex.submit(next(it)))
                except StopIteration:
                    exhausted = True
            if not pending:
                return
            yield pending.popleft().result()
    finally:
        for f in pending:
            f.cancel()
        ex.shutdown(wait=True, cancel_futures=True)


class AsyncWriter:
    """Bounded write-behind queue for store puts (spill / output upload)."""

    def __init__(self, max_inflight: int = 2):
        assert max_inflight >= 1
        self._ex = ThreadPoolExecutor(
            max_workers=max_inflight, thread_name_prefix="stage-write"
        )
        self._slots = threading.Semaphore(max_inflight)
        self._futures: list[Future] = []

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        """Queue fn(*args); blocks while `max_inflight` writes are pending
        (backpressure — the merge-controller ack analogue)."""
        self._slots.acquire()

        def run():
            try:
                return fn(*args, **kwargs)
            finally:
                self._slots.release()

        f = self._ex.submit(run)
        self._futures.append(f)
        return f

    def drain(self) -> None:
        """Wait for all pending writes; re-raises the first failure."""
        futures, self._futures = self._futures, []
        for f in futures:
            f.result()

    def close(self) -> None:
        self.drain()
        self._ex.shutdown(wait=True)

    def __enter__(self) -> "AsyncWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:  # don't mask the in-flight exception; just stop the pool
            self._ex.shutdown(wait=True, cancel_futures=True)
