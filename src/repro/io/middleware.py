"""Composable store middleware: the S3 behaviours the backend doesn't have.

The paper's numbers depend on S3 acting like S3: request latency and
per-connection bandwidth absorbed by pipelined I/O (§2.5), "503 Slow Down"
throttling absorbed by retries, and per-request fees computed from the
requests *actually issued* (§3.3.2). The filesystem backend emulates the
data plane only; each middleware here layers one behaviour over any
StoreBackend, so a realistic endpoint is a composition:

    RetryMiddleware(            # client-side: backoff + re-issue
      MetricsMiddleware(        # counts every attempt (retry-inflated)
        ThrottlingMiddleware(   # service-side: token-bucket 503s
          LatencyBandwidthMiddleware(   # wire: RTT + bytes/bandwidth
            FilesystemBackend(root)))))

Ordering matters and the stack above is the intended one: Metrics sits
*outside* the fault injectors so a throttled attempt is still an issued
(and billed) request, and *inside* Retry so every re-issue is counted —
which is exactly the retry-inflated request count the cost model's access
legs should price (core/cost_model.py). TracingMiddleware (the obs
layer's per-task request attribution) takes the same position, so its
per-attempt events and counters agree with the billed counts exactly.

Every middleware delegates the seven primitives through one `_call`
hook, and wraps multipart sessions so streamed part uploads flow through
the same hook (kind "put"). Derived StoreBackend methods (`put`,
`put_multipart`, `get_chunks`) are inherited, never delegated — they
decompose into primitives on the *outermost* layer, so each ranged chunk
and each part crosses the whole stack exactly once.

How the external-sort plan knobs (core/external_sort.ExternalSortPlan)
meet this stack — the request-shape invariants the middleware sees:

  merge_chunk_bytes / reduce_memory_budget_bytes — every reduce-side
      fetch is one ranged GET of at most merge_chunk_bytes (smaller when
      the global budget's governor apportions less), so the GET token
      bucket and latency injection see many small requests, exactly the
      traffic the paper's 503 regime throttles. The budget bounds
      decoded merge-buffer bytes, NOT request count: shrinking the chunk
      raises GET traffic (and the billed access leg) while lowering
      memory — the § 3.3.2 cost/memory trade made measurable.

  parallel_reducers (x cluster workers) — the number of merge loops
      issuing those GETs concurrently; with KillSwitchMiddleware (below)
      a worker's whole view dies at once, mid-request-stream.

  part_upload_fanout — concurrent put_part PUTs per partition crossing
      the stack out of order; each part is its own billed/throttled/
      retried attempt (_WrappedMultipart), like real S3 UploadPart
      traffic. PUT-bucket pressure scales with
      parallel_reducers x part_upload_fanout.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Callable

from repro.io.backends import (MultipartUpload, ObjectMeta, RetryableError,
                               SlowDown, StoreBackend, StoreStats)
from repro.obs.context import current_context
from repro.obs.events import Tracer


class StoreMiddleware(StoreBackend):
    """Transparent wrapper: every primitive funnels through `_call`.

    `_call(kind, issue, read=..., nbytes=...)` is the single override
    point: `kind` is the request class ("get" | "put" | "head" | "list" |
    "delete" | "bucket"), `issue()` performs the inner call, `read=True`
    marks calls whose result length is the downloaded byte count, and
    `nbytes` carries the upload size for writes. Unknown attributes
    (e.g. `.root`, `.stats`) delegate to the wrapped store.
    """

    def __init__(self, inner: StoreBackend):
        self.inner = inner

    def __getattr__(self, name: str):
        return getattr(self.inner, name)

    def _call(self, kind: str, issue: Callable, *, read: bool = False,
              nbytes: int = 0):
        return issue()

    # -- primitives, funnelled --------------------------------------------

    def create_bucket(self, bucket: str) -> None:
        return self._call("bucket", lambda: self.inner.create_bucket(bucket))

    def get(self, bucket: str, key: str) -> bytes:
        return self._call("get", lambda: self.inner.get(bucket, key), read=True)

    def get_range(self, bucket: str, key: str, start: int, length: int) -> bytes:
        return self._call(
            "get", lambda: self.inner.get_range(bucket, key, start, length),
            read=True)

    def head(self, bucket: str, key: str) -> ObjectMeta:
        return self._call("head", lambda: self.inner.head(bucket, key))

    def list_objects(self, bucket: str, prefix: str = "") -> list[ObjectMeta]:
        return self._call("list", lambda: self.inner.list_objects(bucket, prefix))

    def delete(self, bucket: str, key: str) -> None:
        return self._call("delete", lambda: self.inner.delete(bucket, key))

    def multipart(self, bucket: str, key: str,
                  metadata: dict | None = None) -> MultipartUpload:
        return _WrappedMultipart(self, self.inner.multipart(bucket, key, metadata))

    def _commit_allowed(self) -> None:
        """Hook consulted by `_WrappedMultipart.complete()` before the
        (otherwise free) commit is issued. Raises to refuse. Chained down
        the stack so a kill switch anywhere below fences commits issued
        through sessions opened above it."""
        inner = getattr(self.inner, "_commit_allowed", None)
        if inner is not None:
            inner()


class _WrappedMultipart(MultipartUpload):
    """Routes part uploads of an inner session through the middleware.

    Part-indexed and thread-safe like the sessions it wraps: concurrent
    out-of-order `put_part(index, data)` calls each cross the middleware
    as their own PUT attempt (so a parallel part fan-out is throttled,
    delayed, billed, and retried per part, like real S3 UploadPart
    traffic)."""

    def __init__(self, mw: StoreMiddleware, inner: MultipartUpload):
        self._mw = mw
        self._inner = inner

    def put_part(self, index: int, data: bytes) -> None:
        self._mw._call("put", lambda: self._inner.put_part(index, data),
                       nbytes=len(data))

    def complete(self) -> ObjectMeta:  # free, like S3 CompleteMultipartUpload
        # Not billed/throttled, but still refused once the owning view is
        # dead: a commit that BEGINS after a kill switch trips can never
        # land, which makes request-budget kills pre-commit-deterministic
        # (abort still works — cleanup outlives the host).
        self._mw._commit_allowed()
        return self._inner.complete()

    def abort(self) -> None:
        self._inner.abort()


# ---------------------------------------------------------------------------
# Metrics: the PR-1 request accounting, now a layer
# ---------------------------------------------------------------------------


class MetricsMiddleware(StoreMiddleware):
    """Counts every attempt that crosses it into a StoreStats.

    Placed inside RetryMiddleware and outside ThrottlingMiddleware so the
    counters are retry-inflated: each throttled attempt and each re-issue
    is its own request, as it would be on a real S3 bill/rate budget.
    """

    def __init__(self, inner: StoreBackend, stats: StoreStats | None = None):
        super().__init__(inner)
        self.stats = stats if stats is not None else StoreStats()

    _COUNTER = {"get": "get_requests", "put": "put_requests",
                "head": "head_requests", "list": "list_requests",
                "delete": "delete_requests"}

    def _call(self, kind, issue, *, read=False, nbytes=0):
        field = self._COUNTER.get(kind)
        if field:
            self.stats.add(field, 1)
        try:
            result = issue()
        except SlowDown:
            self.stats.add("throttled", 1)
            raise
        if read:
            self.stats.add("bytes_read", len(result))
        if kind == "put":
            self.stats.add("bytes_written", nbytes)
        return result

    def stats_snapshot(self) -> StoreStats:
        """Consistent copy of the counters (for before/after deltas)."""
        return self.stats.snapshot()


# ---------------------------------------------------------------------------
# Tracing: per-attempt attribution to the issuing task (obs layer)
# ---------------------------------------------------------------------------


class TracingMiddleware(StoreMiddleware):
    """Attributes every request attempt to the task that issued it.

    The observability twin of MetricsMiddleware, and it sits at the same
    stack position (inside RetryMiddleware, outside the fault injectors)
    so its counts are retry-inflated bit-for-bit like the billed ones:
    every attempt — throttled, failed, or served — becomes one child
    span of the current TraceContext (obs/context.py) in the tracer's
    event log, and one `store.requests{kind,outcome[,tier]}` counter
    increment in its registry. Successful reads/writes also add to the
    phase-labeled `store.bytes_read` / `store.bytes_written` counters —
    the per-phase bytes/s the report's metrics derive from.

    Outcomes: "ok", "slowdown" (a 503 the retry layer will re-issue),
    "error" (anything else, e.g. a dead worker's severed store view).
    """

    def __init__(self, inner: StoreBackend, tracer: Tracer, *,
                 tier: str = ""):
        super().__init__(inner)
        self.tracer = tracer
        self.tier = tier

    def _record(self, kind: str, t0: float, outcome: str, nbytes: int,
                *, read: bool = False) -> None:
        reg = self.tracer.registry
        labels = {"kind": kind, "outcome": outcome}
        if self.tier:
            labels["tier"] = self.tier
        reg.counter("store.requests", 1, **labels)
        if outcome == "ok" and nbytes:
            ctx = current_context()
            blabels = {"phase": ctx.phase if ctx else ""}
            if self.tier:
                blabels["tier"] = self.tier
            reg.counter("store.bytes_read" if read else "store.bytes_written",
                        nbytes, **blabels)
        self.tracer.event(f"store.{kind}", t0, time.perf_counter(),
                          outcome=outcome, nbytes=nbytes,
                          tier=self.tier or None)

    def _call(self, kind, issue, *, read=False, nbytes=0):
        if kind == "bucket":  # not a billed request; Metrics skips it too
            return issue()
        t0 = time.perf_counter()
        try:
            result = issue()
        except SlowDown:
            self._record(kind, t0, "slowdown", 0)
            raise
        except BaseException:
            self._record(kind, t0, "error", 0)
            raise
        n = len(result) if read else nbytes
        self._record(kind, t0, "ok", n, read=read)
        return result


# ---------------------------------------------------------------------------
# Latency + bandwidth: the wire
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """S3-like service parameters for the fault-injecting middlewares.

    Zero disables a term. `latency_s` is per-request first-byte latency
    (S3 TTFB is ~10–50 ms); `bandwidth_bps` is per-request streaming
    throughput (~90 MB/s per S3 connection); `get_rate`/`put_rate` are
    token-bucket request rates per second with `burst` capacity (S3
    advertises 5500 GET/s and 3500 PUT/s per prefix before 503s).
    """

    latency_s: float = 0.0
    jitter_s: float = 0.0  # uniform extra latency in [0, jitter_s)
    bandwidth_bps: float = 0.0
    get_rate: float = 0.0
    put_rate: float = 0.0
    burst: float = 32.0


class LatencyBandwidthMiddleware(StoreMiddleware):
    """Sleeps each request by latency + bytes/bandwidth; accounts the stall.

    The sleep is taken with no lock held, so concurrent requests stall
    concurrently — which is precisely what the staging layer's pipelining
    is supposed to hide, and what bench_store_faults measures.
    """

    def __init__(self, inner: StoreBackend, profile: FaultProfile,
                 *, stats: StoreStats | None = None, seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep):
        super().__init__(inner)
        self.profile = profile
        self.stats = stats if stats is not None else StoreStats()
        self._sleep = sleep
        self._rng = random.Random(seed)
        self._rng_lock = threading.Lock()

    def _stall(self, seconds: float) -> None:
        if seconds <= 0:
            return
        self.stats.add("stall_seconds", seconds)
        self._sleep(seconds)

    def _call(self, kind, issue, *, read=False, nbytes=0):
        p = self.profile
        if kind != "bucket":
            if p.jitter_s:
                with self._rng_lock:
                    jitter = self._rng.uniform(0, p.jitter_s)
            else:
                jitter = 0.0
            pre = p.latency_s + jitter
            if nbytes and p.bandwidth_bps:
                pre += nbytes / p.bandwidth_bps  # upload streams before ack
            self._stall(pre)
        result = issue()
        if read and p.bandwidth_bps:
            self._stall(len(result) / p.bandwidth_bps)
        return result


# ---------------------------------------------------------------------------
# Throttling: the service's 503 budget
# ---------------------------------------------------------------------------


class _TokenBucket:
    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        self.rate = float(rate)
        self.capacity = max(float(burst), 1.0)
        self.tokens = self.capacity
        self._clock = clock
        self._t = clock()
        self._lock = threading.Lock()

    def try_acquire(self) -> bool:
        if self.rate <= 0:  # unlimited
            return True
        with self._lock:
            now = self._clock()
            self.tokens = min(self.capacity,
                              self.tokens + (now - self._t) * self.rate)
            self._t = now
            if self.tokens >= 1.0:
                self.tokens -= 1.0
                return True
            return False


class ThrottlingMiddleware(StoreMiddleware):
    """Token-bucket request admission; over-budget attempts raise SlowDown.

    Reads (get) and writes (put/delete) draw from separate buckets,
    mirroring S3's separate GET and PUT rate budgets per prefix. Metadata
    requests (head/list) are not throttled — they're free in Table 2 and
    effectively unlimited in practice.
    """

    def __init__(self, inner: StoreBackend, profile: FaultProfile,
                 *, clock: Callable[[], float] = time.monotonic):
        super().__init__(inner)
        self.profile = profile
        self._read_bucket = _TokenBucket(profile.get_rate, profile.burst, clock)
        self._write_bucket = _TokenBucket(profile.put_rate, profile.burst, clock)

    def _call(self, kind, issue, *, read=False, nbytes=0):
        bucket = None
        if kind == "get":
            bucket = self._read_bucket
        elif kind in ("put", "delete"):
            bucket = self._write_bucket
        if bucket is not None and not bucket.try_acquire():
            raise SlowDown(f"503 Slow Down ({kind})")
        return issue()


# ---------------------------------------------------------------------------
# Kill switch: emulated host death (core/cluster.py's failure domain)
# ---------------------------------------------------------------------------


class KillSwitchMiddleware(StoreMiddleware):
    """Emulated host death for one worker's view of a shared store.

    Once tripped — explicitly via `trip()` (core/cluster.FaultyWorker) or
    automatically after `fail_after_requests` served requests — every
    subsequent request raises `exc_factory()`. The exception should NOT
    be a RetryableError: a dead host does not come back on backoff, so
    the store-level retry stack must propagate it to the cluster driver,
    whose task re-execution is the correct recovery. Requests refused by
    a tripped switch never reach inner layers, so they are not billed or
    throttled — a dead worker stops generating traffic, it doesn't
    generate errors on the bill.
    """

    def __init__(self, inner: StoreBackend, *,
                 exc_factory: Callable[[], BaseException],
                 fail_after_requests: int | None = None):
        super().__init__(inner)
        self._exc_factory = exc_factory
        self._budget = fail_after_requests
        self._lock = threading.Lock()
        self._tripped = threading.Event()

    @property
    def tripped(self) -> bool:
        return self._tripped.is_set()

    def trip(self) -> None:
        self._tripped.set()

    def _call(self, kind, issue, *, read=False, nbytes=0):
        if self._tripped.is_set():
            raise self._exc_factory()
        if self._budget is not None and kind != "bucket":
            with self._lock:
                if self._budget <= 0:
                    self._tripped.set()
                else:
                    self._budget -= 1
            if self._tripped.is_set():
                raise self._exc_factory()
        return issue()

    def _commit_allowed(self) -> None:
        # Serialized with the budget decrement so a multipart complete
        # and the request that trips the switch are strictly ordered: a
        # commit starting after the trip is refused, never durable.
        with self._lock:
            if self._tripped.is_set():
                raise self._exc_factory()
        super()._commit_allowed()


# ---------------------------------------------------------------------------
# Retry: the client's backoff loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with multiplicative jitter (the AWS SDK
    default shape): attempt k sleeps min(base * 2^k, max_delay) scaled by
    a uniform factor in [1 - jitter, 1]."""

    max_attempts: int = 8  # total attempts, including the first
    base_delay_s: float = 0.02
    max_delay_s: float = 1.0
    jitter: float = 0.5

    def delay(self, attempt: int, rng: random.Random) -> float:
        d = min(self.base_delay_s * (2.0 ** attempt), self.max_delay_s)
        return d * (1.0 - self.jitter * rng.random())


class RetryMiddleware(StoreMiddleware):
    """Re-issues attempts that fail with a RetryableError (e.g. SlowDown).

    Sits outermost so each re-issue re-traverses metrics/throttling/
    latency — a retry is a brand-new request. When attempts are
    exhausted the *original* error propagates; `stats.retries` counts
    re-issues and `stats.stall_seconds` the backoff sleeps.
    """

    def __init__(self, inner: StoreBackend, policy: RetryPolicy = RetryPolicy(),
                 *, stats: StoreStats | None = None, seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep,
                 tracer: Tracer | None = None):
        super().__init__(inner)
        self.policy = policy
        self.stats = stats if stats is not None else StoreStats()
        self._sleep = sleep
        self._rng = random.Random(seed)
        self._rng_lock = threading.Lock()
        self.tracer = tracer

    def _call(self, kind, issue, *, read=False, nbytes=0):
        attempt = 0
        while True:
            try:
                return issue()
            except RetryableError:
                attempt += 1
                if attempt >= self.policy.max_attempts:
                    raise
                with self._rng_lock:
                    delay = self.policy.delay(attempt - 1, self._rng)
                self.stats.add("retries", 1)
                self.stats.add("stall_seconds", delay)
                if self.tracer is not None:
                    self.tracer.registry.counter("store.retries", 1, kind=kind)
                    self.tracer.registry.observe("store.retry_delay_s", delay,
                                                 kind=kind)
                    self.tracer.instant("store.retry", kind=kind,
                                        attempt=attempt, delay_s=delay)
                self._sleep(delay)


def fault_injected(backend: StoreBackend, *, profile: FaultProfile,
                   retry: RetryPolicy | None = RetryPolicy(),
                   seed: int = 0, tracer: Tracer | None = None,
                   tier: str = "") -> StoreBackend:
    """Compose the canonical stack around `backend` with one shared
    StoreStats: Retry(Tracing?(Metrics(Throttle(Latency(backend))))).

    Pass `retry=None` to expose raw SlowDowns to the caller (tests, or a
    client that does its own backoff). With a `tracer`, a
    TracingMiddleware rides at the MetricsMiddleware position (inside
    Retry, outside the fault injectors) so per-task attribution counts
    the same retry-inflated attempts the bill does; `tier` labels its
    events (e.g. "durable" / "ssd"). The returned store duck-types the
    PR-1 ObjectStore: `.stats` / `.stats_snapshot()` reach the shared
    counters via attribute delegation.
    """
    stats = StoreStats()
    store: StoreBackend = LatencyBandwidthMiddleware(
        backend, profile, stats=stats, seed=seed)
    store = ThrottlingMiddleware(store, profile)
    store = MetricsMiddleware(store, stats=stats)
    if tracer is not None:
        store = TracingMiddleware(store, tracer, tier=tier)
    if retry is not None:
        store = RetryMiddleware(store, retry, stats=stats, seed=seed + 1,
                                tracer=tracer)
    return store
