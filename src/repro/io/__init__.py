"""Object-store I/O subsystem (paper §2.2–§2.5).

  object_store — filesystem-backed S3-contract emulation with per-request
                 GET/PUT accounting (feeds the Table-2 TCO model)
  records      — interleaved (key, id, payload) record-block codec
  staging      — async double-buffered host<->device staging

`core/external_sort.py` composes these into the out-of-core CloudSort
driver: dataset size is bounded by store capacity, not HBM.
"""
from repro.io.object_store import ObjectMeta, ObjectNotFound, ObjectStore, StoreStats
from repro.io.records import (body_range, decode_body, decode_header,
                              decode_records, encode_records, record_bytes)
from repro.io.staging import AsyncWriter, prefetch

__all__ = [
    "ObjectMeta", "ObjectNotFound", "ObjectStore", "StoreStats",
    "body_range", "decode_body", "decode_header", "decode_records",
    "encode_records", "record_bytes",
    "AsyncWriter", "prefetch",
]
