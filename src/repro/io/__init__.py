"""Object-store I/O subsystem (paper §2.2–§2.5).

  backends     — StoreBackend protocol; filesystem + in-memory data planes
  middleware   — latency/bandwidth, 503 throttling, retry/backoff, metrics
                 layers composable over any backend
  tiered       — TieredStore: local-SSD spill tier + durable (S3-like) tier
  object_store — ObjectStore, the metrics-wrapped filesystem composition
                 (the PR-1 surface, unchanged for existing consumers)
  records      — interleaved (key, id, payload) record-block codec
  staging      — async double-buffered host<->device staging

`core/external_sort.py` composes these into the out-of-core CloudSort
driver: dataset size is bounded by store capacity, not HBM.
"""
from repro.io.backends import (FilesystemBackend, IntegrityError,
                               MemoryBackend, MultipartUpload, ObjectMeta,
                               ObjectNotFound, RetryableError, SlowDown,
                               StoreBackend, StoreStats)
from repro.io.middleware import (FaultProfile, LatencyBandwidthMiddleware,
                                 MetricsMiddleware, RetryMiddleware,
                                 RetryPolicy, StoreMiddleware,
                                 ThrottlingMiddleware, fault_injected)
from repro.io.object_store import ObjectStore
from repro.io.records import (body_range, decode_body, decode_header,
                              decode_records, encode_body, encode_header,
                              encode_records, record_bytes)
from repro.io.staging import AsyncWriter, prefetch
from repro.io.tiered import TieredStore, tiered_cloudsort_store

__all__ = [
    "FilesystemBackend", "IntegrityError", "MemoryBackend", "MultipartUpload",
    "ObjectMeta", "ObjectNotFound", "ObjectStore", "RetryableError",
    "SlowDown", "StoreBackend", "StoreStats",
    "FaultProfile", "LatencyBandwidthMiddleware", "MetricsMiddleware",
    "RetryMiddleware", "RetryPolicy", "StoreMiddleware",
    "ThrottlingMiddleware", "fault_injected",
    "TieredStore", "tiered_cloudsort_store",
    "body_range", "decode_body", "decode_header", "decode_records",
    "encode_body", "encode_header", "encode_records", "record_bytes",
    "AsyncWriter", "prefetch",
]
