"""ObjectStore: the PR-1 entry point, now a composition over io/backends.

Exoshuffle-CloudSort keeps the *entire* dataset in S3 (§2.2) and the TCO
model charges *per request* (§3.3.2, Table 2), so faithful request
accounting is part of the reproduction. PR 1 implemented that as one
concrete filesystem class; the I/O stack is now layered (the multi-layer
refactor of ISSUE 2):

  io/backends.py   — StoreBackend protocol + FilesystemBackend (the old
                     data plane, CRC-verified reads) + MemoryBackend
  io/middleware.py — Latency/Bandwidth, Throttling (503 Slow Down),
                     Retry (backoff), Metrics (the old `stats` counters)
  io/tiered.py     — TieredStore: local-SSD spill tier + durable tier

`ObjectStore(root)` keeps its PR-1 surface exactly — a metrics-wrapped
filesystem backend: put / put_multipart / get / get_range / get_chunks /
head / list_objects / delete, per-bucket persistent manifests, `.stats`
and `.stats_snapshot()` — so every existing consumer works unchanged,
while new code composes backends and middleware directly.
"""
from __future__ import annotations

from repro.io.backends import (FilesystemBackend, IntegrityError,
                               MemoryBackend, MultipartUpload, ObjectMeta,
                               ObjectNotFound, RetryableError, SlowDown,
                               StoreBackend, StoreStats)
from repro.io.middleware import MetricsMiddleware

__all__ = [
    "FilesystemBackend", "IntegrityError", "MemoryBackend", "MultipartUpload",
    "ObjectMeta", "ObjectNotFound", "ObjectStore", "RetryableError",
    "SlowDown", "StoreBackend", "StoreStats",
]


class ObjectStore(MetricsMiddleware):
    """One store = one S3 endpoint on the local filesystem, with request
    accounting — MetricsMiddleware(FilesystemBackend(root)).

    `root` and `chunk_size` resolve to the underlying backend via
    attribute delegation, so reopening (`ObjectStore(store.root)`) and
    per-call chunk sizing behave exactly as before the refactor.
    """

    def __init__(self, root: str, *, chunk_size: int = 4 << 20):
        super().__init__(FilesystemBackend(root, chunk_size=chunk_size))
