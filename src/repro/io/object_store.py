"""Filesystem-backed object store emulating the paper's S3 contract.

Exoshuffle-CloudSort keeps the *entire* dataset in S3 (§2.2): map tasks
download input partitions in ranged chunks, merged runs spill to local
storage, and reduce tasks upload output partitions as multipart objects.
The TCO model (§3.3.2, Table 2) then charges *per request* — 6M GETs and
1M PUTs at 100 TB — so faithful request accounting is part of the
reproduction, not an afterthought.

This store emulates exactly the S3 surface the paper exercises, on the
local filesystem:

  put / put_multipart      — 1 PUT counted per object / per uploaded part
                             (the paper's "25k reduces x 40 chunks = 1M PUTs")
  get / get_range / get_chunks
                           — 1 GET counted per call / per ranged chunk
                             (the paper's "50k maps x 120 chunks = 6M GETs")
  head / list_objects      — metadata; counted separately, free in Table 2
  bucket manifest          — JSON per bucket, persisted so a store can be
                             reopened (the S3 namespace survives process
                             death, unlike worker memory)

What is deliberately NOT emulated: network latency/bandwidth, eventual
consistency, request rate limits, and retry semantics (see ROADMAP.md
"I/O layer"). `core/external_sort.py` drives real byte movement through
this store so dataset size is bounded by store capacity, not HBM.

Thread-safe: the staging layer (io/staging.py) issues puts/gets from
background threads to overlap I/O with device compute (§2.5).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import zlib
from typing import Iterable, Iterator


class ObjectNotFound(KeyError):
    """Missing bucket or key (the S3 404)."""


@dataclasses.dataclass
class StoreStats:
    """Cumulative request/byte counters — the measured Table-2 inputs."""

    get_requests: int = 0
    put_requests: int = 0
    head_requests: int = 0
    list_requests: int = 0
    bytes_read: int = 0
    bytes_written: int = 0

    def __sub__(self, other: "StoreStats") -> "StoreStats":
        return StoreStats(**{
            f.name: getattr(self, f.name) - getattr(other, f.name)
            for f in dataclasses.fields(self)
        })


@dataclasses.dataclass(frozen=True)
class ObjectMeta:
    """Manifest entry: what `head` returns (S3 HeadObject)."""

    key: str
    size: int
    etag: str  # crc32 of the object bytes
    parts: int  # 1 for plain puts, #parts for multipart uploads
    metadata: dict


_MANIFEST = "manifest.json"
_OBJECTS = "objects"


def _check_key(key: str) -> str:
    assert key and not key.startswith(("/", ".")), f"bad object key {key!r}"
    assert ".." not in key.split("/"), f"bad object key {key!r}"
    return key


class ObjectStore:
    """One store = one S3 endpoint; buckets hold objects under `root`."""

    def __init__(self, root: str, *, chunk_size: int = 4 << 20):
        self.root = root
        self.chunk_size = int(chunk_size)  # default ranged-GET granularity
        self.stats = StoreStats()
        self._lock = threading.Lock()
        self._manifests: dict[str, dict[str, dict]] = {}
        self._flush_locks: dict[str, threading.Lock] = {}
        os.makedirs(root, exist_ok=True)
        for bucket in sorted(os.listdir(root)):
            mpath = os.path.join(root, bucket, _MANIFEST)
            if os.path.isfile(mpath):
                with open(mpath) as f:
                    self._manifests[bucket] = json.load(f)
                self._flush_locks[bucket] = threading.Lock()

    # -- namespace ---------------------------------------------------------

    def create_bucket(self, bucket: str) -> None:
        os.makedirs(os.path.join(self.root, bucket, _OBJECTS), exist_ok=True)
        with self._lock:
            self._manifests.setdefault(bucket, {})
            self._flush_locks.setdefault(bucket, threading.Lock())
        self._flush_manifest(bucket)

    def _object_path(self, bucket: str, key: str) -> str:
        return os.path.join(self.root, bucket, _OBJECTS, *_check_key(key).split("/"))

    def _flush_manifest(self, bucket: str) -> None:
        """Persist the bucket manifest. The JSON dump happens OUTSIDE the
        store-wide lock so concurrent staging writers only contend on the
        cheap dict update, not the file I/O; a per-bucket flush lock keeps
        file writes ordered, and the snapshot is re-taken under the main
        lock so the last flusher always persists the newest state."""
        with self._flush_locks[bucket]:
            with self._lock:
                snapshot = dict(self._manifests[bucket])
            mpath = os.path.join(self.root, bucket, _MANIFEST)
            tmp = f"{mpath}.{threading.get_ident()}.tmp"
            with open(tmp, "w") as f:
                json.dump(snapshot, f)
            os.replace(tmp, mpath)

    def _entry(self, bucket: str, key: str) -> dict:
        try:
            return self._manifests[bucket][key]
        except KeyError:
            raise ObjectNotFound(f"{bucket}/{key}") from None

    def _meta(self, key: str, e: dict) -> ObjectMeta:
        return ObjectMeta(key=key, size=e["size"], etag=e["etag"],
                          parts=e["parts"], metadata=dict(e["metadata"]))

    # -- writes ------------------------------------------------------------

    def put(self, bucket: str, key: str, data: bytes,
            metadata: dict | None = None) -> ObjectMeta:
        """S3 PutObject: one PUT request."""
        return self._write(bucket, key, [bytes(data)], metadata)

    def put_multipart(self, bucket: str, key: str, parts: Iterable[bytes],
                      metadata: dict | None = None) -> ObjectMeta:
        """S3 multipart upload: one PUT request counted per part.

        (The paper's request arithmetic — 40 upload chunks per reduce task
        — counts exactly the part uploads; initiate/complete are free.)
        """
        return self._write(bucket, key, [bytes(p) for p in parts], metadata)

    def _write(self, bucket, key, parts: list[bytes], metadata) -> ObjectMeta:
        if bucket not in self._manifests:
            raise ObjectNotFound(bucket)
        path = self._object_path(bucket, key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        crc = 0
        size = 0
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            for p in parts:
                f.write(p)
                crc = zlib.crc32(p, crc)
                size += len(p)
        os.replace(tmp, path)
        entry = {"size": size, "etag": f"{crc:08x}", "parts": max(len(parts), 1),
                 "metadata": dict(metadata or {})}
        with self._lock:
            self._manifests[bucket][key] = entry
            self.stats.put_requests += max(len(parts), 1)
            self.stats.bytes_written += size
        self._flush_manifest(bucket)
        return self._meta(key, entry)

    # -- reads -------------------------------------------------------------

    def get(self, bucket: str, key: str) -> bytes:
        """S3 GetObject (whole object): one GET request."""
        e = self._entry(bucket, key)
        with open(self._object_path(bucket, key), "rb") as f:
            data = f.read()
        assert len(data) == e["size"]
        with self._lock:
            self.stats.get_requests += 1
            self.stats.bytes_read += len(data)
        return data

    def get_range(self, bucket: str, key: str, start: int, length: int) -> bytes:
        """S3 ranged GET: one GET request; truncates at object end like S3."""
        e = self._entry(bucket, key)
        start = max(int(start), 0)
        length = min(int(length), max(e["size"] - start, 0))
        with open(self._object_path(bucket, key), "rb") as f:
            f.seek(start)
            data = f.read(length)
        with self._lock:
            self.stats.get_requests += 1
            self.stats.bytes_read += len(data)
        return data

    def get_chunks(self, bucket: str, key: str,
                   chunk_size: int | None = None) -> Iterator[bytes]:
        """Download an object as ranged chunks — the paper's map download
        pattern (one GET per chunk, §3.3.2's "120 chunks" per map task)."""
        e = self._entry(bucket, key)
        step = int(chunk_size or self.chunk_size)
        assert step > 0
        offsets = range(0, e["size"], step) if e["size"] else (0,)
        for off in offsets:
            yield self.get_range(bucket, key, off, step)

    # -- metadata ----------------------------------------------------------

    def head(self, bucket: str, key: str) -> ObjectMeta:
        e = self._entry(bucket, key)
        with self._lock:
            self.stats.head_requests += 1
        return self._meta(key, e)

    def list_objects(self, bucket: str, prefix: str = "") -> list[ObjectMeta]:
        """S3 ListObjects: manifest entries under `prefix`, key-sorted."""
        if bucket not in self._manifests:
            raise ObjectNotFound(bucket)
        with self._lock:
            self.stats.list_requests += 1
            items = sorted(self._manifests[bucket].items())
        return [self._meta(k, e) for k, e in items if k.startswith(prefix)]

    def delete(self, bucket: str, key: str) -> None:
        self._entry(bucket, key)
        os.remove(self._object_path(bucket, key))
        with self._lock:
            del self._manifests[bucket][key]
        self._flush_manifest(bucket)

    def stats_snapshot(self) -> StoreStats:
        """Consistent copy of the counters (for before/after deltas)."""
        with self._lock:
            return dataclasses.replace(self.stats)
