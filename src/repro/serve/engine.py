"""Batched serving engine: prefill once, decode greedily with a KV cache.

Minimal but real: static-shape batched decode (jit'd step), greedy or
temperature sampling, per-sequence stop handling via an alive mask. Used
by examples/serve_decode.py and the decode benchmark cells.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.api import ModelApi


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    eos_id: int = -1  # -1: never stop early


def generate(model: ModelApi, params, batch, cfg: ServeConfig,
             *, rng=None):
    """batch: the prefill inputs (tokens [+frames/patch_embeds]).

    Returns (generated (B, max_new_tokens) int32, steps executed).
    """
    prompt = batch["tokens"]
    b, s = prompt.shape
    prefix = getattr(model.cfg, "vlm_prefix", 0) if model.cfg.family == "vlm" else 0
    max_len = s + prefix + cfg.max_new_tokens + 1
    logits, cache = model.prefill(params, batch, max_len=max_len)

    step_fn = jax.jit(model.decode_step)
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    def sample(lg, key):
        lg = lg[:, -1]
        if cfg.temperature > 0:
            return jax.random.categorical(key, lg / cfg.temperature)
        return jnp.argmax(lg, axis=-1)

    toks = []
    key = rng
    key, sub = jax.random.split(key)
    nxt = sample(logits, sub).astype(jnp.int32)
    alive = jnp.ones((b,), bool)
    pos = s + prefix
    for _ in range(cfg.max_new_tokens):
        nxt = jnp.where(alive, nxt, 0)
        toks.append(nxt)
        if cfg.eos_id >= 0:
            alive = alive & (nxt != cfg.eos_id)
        logits, cache = step_fn(params, cache, nxt[:, None], jnp.int32(pos))
        key, sub = jax.random.split(key)
        nxt = sample(logits, sub).astype(jnp.int32)
        pos += 1
    return jnp.stack(toks, axis=1), cfg.max_new_tokens
