"""Diff benchmark artifacts against committed baselines (the CI gate).

Usage:
    python tools/bench_diff.py --baseline benchmarks/baselines \\
                               --current bench-out [--tolerance 0.25]

Both directories hold ``BENCH_<name>.json`` artifacts written by
``benchmarks/run.py --artifact`` (schema 1: rows keyed by name with
us/derived, plus the bench module's ``GATES`` declarations). For every
artifact present in *both* directories, each gated row's ``derived``
value is compared:

  * direction "lower" (the default): current may exceed baseline by at
    most ``tolerance`` (relative) before it's a regression;
  * direction "higher": current may fall below baseline by at most
    ``tolerance``.

Only gated rows are compared — timings and throughputs are recorded in
the artifacts for trend inspection but never gated, because CI runners
are noisy; the gated rows (request counts, TCO) are deterministic
functions of the plan. A bench whose current status is "skip" passes (an
environment that can't run the bench is not a regression); a current
"fail" status fails the diff. Missing baselines warn and pass, so the
gate bootstraps cleanly when a new bench lands before its baseline.

Exit code: 0 = no gated regressions, 1 = at least one.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_artifacts(directory: str) -> dict[str, dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(directory, "BENCH_*.json"))):
        with open(path) as f:
            art = json.load(f)
        out[art.get("bench", os.path.basename(path))] = art
    return out


def relative_change(base: float, cur: float) -> float:
    """(cur - base) / |base|; an exact-zero baseline compares exactly."""
    if base == 0:
        return 0.0 if cur == 0 else float("inf")
    return (cur - base) / abs(base)


def diff_bench(name: str, base: dict, cur: dict,
               default_tolerance: float) -> list[str]:
    """Returns regression messages for one bench (empty = pass)."""
    if cur.get("status") == "skip":
        print(f"  {name}: skipped in current run ({cur.get('error')}) — ok")
        return []
    if cur.get("status") == "fail":
        return [f"{name}: bench FAILED in current run: {cur.get('error')}"]
    if base.get("status") != "ok":
        print(f"  {name}: baseline status {base.get('status')!r} — "
              "nothing to compare")
        return []

    regressions = []
    gates = cur.get("gates") or base.get("gates") or {}
    for row, gate in sorted(gates.items()):
        tol = float(gate.get("tolerance", default_tolerance))
        direction = gate.get("direction", "lower")
        b = base.get("rows", {}).get(row)
        c = cur.get("rows", {}).get(row)
        if b is None or c is None:
            missing = "baseline" if b is None else "current"
            regressions.append(f"{name}/{row}: gated row missing from "
                               f"{missing} artifact")
            continue
        change = relative_change(b["derived"], c["derived"])
        worse = change > tol if direction == "lower" else change < -tol
        arrow = f"{b['derived']:.6g} -> {c['derived']:.6g} ({change:+.1%})"
        if worse:
            regressions.append(
                f"{name}/{row}: {arrow} exceeds {tol:.0%} tolerance "
                f"(direction: {direction} is better)")
        else:
            better = change < 0 if direction == "lower" else change > 0
            tag = "improved" if better else "ok"
            print(f"  {name}/{row}: {arrow} {tag}")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="directory of committed BENCH_*.json baselines")
    ap.add_argument("--current", required=True,
                    help="directory of this run's BENCH_*.json artifacts")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="default relative tolerance for gates that don't "
                         "declare one (default: 0.25)")
    args = ap.parse_args(argv)

    baselines = load_artifacts(args.baseline)
    currents = load_artifacts(args.current)
    if not currents:
        print(f"error: no BENCH_*.json artifacts under {args.current}",
              file=sys.stderr)
        return 1

    regressions: list[str] = []
    for name, cur in sorted(currents.items()):
        base = baselines.get(name)
        if base is None:
            print(f"  {name}: no baseline yet — record one by committing "
                  f"this artifact to {args.baseline}/")
            continue
        regressions += diff_bench(name, base, cur, args.tolerance)

    if regressions:
        print("\nGATED REGRESSIONS:", file=sys.stderr)
        for msg in regressions:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print("\nbench diff: all gates green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
