"""Fail on broken relative links in the repo's markdown docs.

Scans README.md plus every .md under docs/ (and the other top-level .md
files) for inline markdown links/images `[text](target)`. Relative
targets must resolve to an existing file or directory; external schemes
(http/https/mailto) and pure in-page anchors (#...) are skipped, and a
`path#fragment` target is checked for the path part only.

CI runs this as the docs job; run locally with:

    python tools/check_links.py
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — target up to the first unescaped ')'; images too.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def md_files() -> list[str]:
    files = [os.path.join(REPO, name) for name in sorted(os.listdir(REPO))
             if name.endswith(".md")]
    docs = os.path.join(REPO, "docs")
    for root, _, names in os.walk(docs):
        files += [os.path.join(root, n) for n in sorted(names)
                  if n.endswith(".md")]
    return [f for f in files if os.path.isfile(f)]


def check_file(path: str) -> list[str]:
    errors = []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP_SCHEMES) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        base = REPO if rel.startswith("/") else os.path.dirname(path)
        resolved = os.path.normpath(os.path.join(base, rel.lstrip("/")))
        if not os.path.exists(resolved):
            line = text.count("\n", 0, m.start()) + 1
            errors.append(f"{os.path.relpath(path, REPO)}:{line}: "
                          f"broken link -> {target}")
    return errors


def main() -> int:
    files = md_files()
    errors = []
    for path in files:
        errors += check_file(path)
    for err in errors:
        print(err, file=sys.stderr)
    print(f"checked {len(files)} markdown files, "
          f"{len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
